//! DSE problem definition: which CDFG nodes are being folded, what counts
//! against the budget, and what II is being minimized.
//!
//! The paper generates *separate* TAP functions for each stage of the EE
//! network (§III-A) by giving the optimizer "limited fractions of the
//! board resource constraints". A `Problem` captures one such sub-design:
//! the baseline backbone, or EE pipeline section `i` — its backbone
//! nodes, its exit branch (when it has one), and (for section 0, the
//! full-rate front) the Egress. The number of sections is data, not part
//! of the type.

use crate::ir::{Cdfg, StageId};
use crate::resources::{model, ResourceVec};
use crate::sdf::HwMapping;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProblemKind {
    /// Single-stage baseline network (whole backbone, full rate).
    Baseline,
    /// EE pipeline section `i`: `Stage(0)` is the paper's stage 1
    /// (everything at the input sample rate), `Stage(i)` for `i > 0` the
    /// section behind Conditional Buffer `i - 1`.
    Stage(usize),
}

/// What the search optimizes (the paper reports both headline shapes:
/// maximum throughput under a budget, Fig. 9's speedup claim, and the
/// cheapest design matching a throughput target, the "46% of the
/// resources" claim).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Objective {
    /// Maximize throughput under the problem's resource budget — the
    /// original (and default) mode.
    MaxThroughput,
    /// Minimize the scalar area norm
    /// ([`ResourceVec::utilization`](crate::resources::ResourceVec::utilization)
    /// against the budget) subject to throughput ≥ the target in
    /// samples/s. The annealer's energy trades area for a throughput
    /// shortfall penalty; `dse::pareto::min_area_design` wraps this with
    /// a frontier fallback so the outcome is never worse than the best
    /// swept point.
    MinAreaAtThroughput(f64),
    /// Trace the whole throughput/area frontier. A single anneal under
    /// this objective is **bit-identical** to [`Objective::MaxThroughput`]
    /// (the frontier mode is a sweep of per-budget max-throughput
    /// searches — `dse::pareto::sweep_frontier` supplies the budget
    /// ladder; property-tested in `tests/pareto_props.rs`).
    ParetoFront,
}

/// One DSE instance over a node subset of a mapping.
#[derive(Clone, Debug)]
pub struct Problem {
    pub kind: ProblemKind,
    pub mapping: HwMapping,
    /// Node ids whose folding the search mutates and whose resources are
    /// charged against the budget.
    pub active: Vec<usize>,
    pub budget: ResourceVec,
    pub clock_hz: f64,
    /// What the annealer's energy rewards (default
    /// [`Objective::MaxThroughput`]).
    pub objective: Objective,
}

impl Problem {
    pub fn baseline(cdfg: Cdfg, budget: ResourceVec, clock_hz: f64) -> Problem {
        let mapping = HwMapping::minimal(cdfg);
        let active = (0..mapping.cdfg.nodes.len()).collect();
        Problem {
            kind: ProblemKind::Baseline,
            mapping,
            active,
            budget,
            clock_hz,
            objective: Objective::MaxThroughput,
        }
    }

    /// The DSE problem for EE pipeline section `sec`: its backbone
    /// nodes and exit branch, plus the Egress for the full-rate front
    /// (section 0).
    pub fn stage(sec: usize, cdfg: Cdfg, budget: ResourceVec, clock_hz: f64) -> Problem {
        let mapping = HwMapping::minimal(cdfg);
        let active = mapping
            .cdfg
            .nodes
            .iter()
            .filter(|n| match n.stage {
                StageId::Backbone(i) | StageId::ExitBranch(i) => i == sec,
                StageId::Egress => sec == 0,
            })
            .map(|n| n.id)
            .collect();
        Problem {
            kind: ProblemKind::Stage(sec),
            mapping,
            active,
            budget,
            clock_hz,
            objective: Objective::MaxThroughput,
        }
    }

    /// Build a problem for a planned sweep kind.
    pub fn for_kind(kind: ProblemKind, cdfg: Cdfg, budget: ResourceVec, clock_hz: f64) -> Problem {
        match kind {
            ProblemKind::Baseline => Problem::baseline(cdfg, budget, clock_hz),
            ProblemKind::Stage(sec) => Problem::stage(sec, cdfg, budget, clock_hz),
        }
    }

    /// Replace the search objective (builder-style; constructors default
    /// to [`Objective::MaxThroughput`]).
    pub fn with_objective(mut self, objective: Objective) -> Problem {
        self.objective = objective;
        self
    }

    /// Whether this problem kind hosts the shared I/O infrastructure.
    /// It is charged to Baseline and to Stage(0) (which own the I/O
    /// path); later sections' shares arrive via the TAP combination's
    /// shared-budget form.
    pub fn charges_infrastructure(kind: ProblemKind) -> bool {
        matches!(kind, ProblemKind::Baseline | ProblemKind::Stage(0))
    }

    /// II being minimized: max over the active nodes.
    pub fn ii(&self, mapping: &HwMapping) -> u64 {
        self.active
            .iter()
            .map(|&id| mapping.node_ii(id))
            .max()
            .unwrap_or(1)
    }

    /// Resources charged to this problem (see
    /// [`Problem::charges_infrastructure`]).
    pub fn resources(&self, mapping: &HwMapping) -> ResourceVec {
        let mut total = if Self::charges_infrastructure(self.kind) {
            model::infrastructure()
        } else {
            ResourceVec::ZERO
        };
        for &id in &self.active {
            total += mapping.node_resources(id);
        }
        total
    }

    pub fn feasible(&self, mapping: &HwMapping) -> bool {
        self.resources(mapping).fits_in(&self.budget)
    }

    /// Throughput at the nominal (unscaled) rate for a mapping.
    pub fn throughput(&self, mapping: &HwMapping) -> f64 {
        self.clock_hz / self.ii(mapping) as f64
    }

    /// Clip a mapping found under a *larger* budget into this problem's
    /// budget: while the charged resources overflow, step down one
    /// folding axis of the most area-hungry steppable active node
    /// (first-max in id order breaks ties). A pure function of
    /// (mapping, budget) — no RNG — so the warm-start chains in
    /// `dse::pareto::sweep_frontier` are reproducible. Parallelism
    /// strictly decreases every step, so the loop terminates; if the
    /// mapping is fully stepped down and still overflows (infrastructure
    /// alone can exceed a tiny budget) the minimal mapping is returned
    /// as-is and the annealer's overrun penalty takes it from there.
    pub fn clip_into_budget(&self, mapping: &HwMapping) -> HwMapping {
        use crate::sdf::folding::FoldingSpace;
        use crate::sdf::Folding;
        let mut m = mapping.clone();
        loop {
            if self.resources(&m).fits_in(&self.budget) {
                return m;
            }
            let mut pick: Option<(f64, usize)> = None;
            for &id in &self.active {
                let f = m.foldings[id];
                let space = &m.spaces[id];
                let can_step = FoldingSpace::step(&space.coarse_out, f.coarse_out, false)
                    .is_some()
                    || FoldingSpace::step(&space.coarse_in, f.coarse_in, false).is_some()
                    || FoldingSpace::step(&space.fine, f.fine, false).is_some();
                if !can_step {
                    continue;
                }
                let u = m.node_resources(id).max_utilisation(&self.budget);
                if pick.as_ref().map(|(b, _)| u > *b).unwrap_or(true) {
                    pick = Some((u, id));
                }
            }
            let Some((_, id)) = pick else {
                return m;
            };
            let f = m.foldings[id];
            let space = &m.spaces[id];
            if let Some(v) = FoldingSpace::step(&space.coarse_out, f.coarse_out, false) {
                m.foldings[id] = Folding { coarse_out: v, ..f };
            } else if let Some(v) = FoldingSpace::step(&space.coarse_in, f.coarse_in, false) {
                m.foldings[id] = Folding { coarse_in: v, ..f };
            } else if let Some(v) = FoldingSpace::step(&space.fine, f.fine, false) {
                m.foldings[id] = Folding { fine: v, ..f };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;
    use crate::resources::Board;

    #[test]
    fn stage_problems_partition_std_nodes() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cdfg = Cdfg::lower(&net, 8);
        let p1 = Problem::stage(0, cdfg.clone(), board.resources, board.clock_hz);
        let p2 = Problem::stage(1, cdfg.clone(), board.resources, board.clock_hz);
        // Disjoint and jointly exhaustive over the CDFG.
        for id in &p1.active {
            assert!(!p2.active.contains(id));
        }
        assert_eq!(p1.active.len() + p2.active.len(), cdfg.nodes.len());
    }

    #[test]
    fn three_exit_stage_problems_partition() {
        let net = testnet::three_exit();
        let board = Board::zc706();
        let cdfg = Cdfg::lower(&net, 4);
        let probs: Vec<Problem> = (0..cdfg.n_sections)
            .map(|i| Problem::stage(i, cdfg.clone(), board.resources, board.clock_hz))
            .collect();
        let total: usize = probs.iter().map(|p| p.active.len()).sum();
        assert_eq!(total, cdfg.nodes.len());
        for (i, a) in probs.iter().enumerate() {
            for b in probs.iter().skip(i + 1) {
                for id in &a.active {
                    assert!(!b.active.contains(id), "node {id} owned by two stages");
                }
            }
        }
        // Infrastructure: charged exactly to baseline and section 0.
        assert!(Problem::charges_infrastructure(ProblemKind::Baseline));
        assert!(Problem::charges_infrastructure(ProblemKind::Stage(0)));
        assert!(!Problem::charges_infrastructure(ProblemKind::Stage(1)));
        assert!(!Problem::charges_infrastructure(ProblemKind::Stage(2)));
    }

    #[test]
    fn minimal_mapping_feasible_on_board() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.resources,
            board.clock_hz,
        );
        assert!(p.feasible(&p.mapping));
        assert!(p.throughput(&p.mapping) > 0.0);
    }

    #[test]
    fn clip_into_budget_is_deterministic_and_feasible_when_possible() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        // A fully-unfolded mapping under the full board…
        let big = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.resources,
            board.clock_hz,
        );
        let mut fat = big.mapping.clone();
        for i in 0..fat.foldings.len() {
            fat.foldings[i] = fat.spaces[i].max();
        }
        // …clipped into a quarter of the board.
        let small = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.budget(0.25),
            board.clock_hz,
        );
        let a = small.clip_into_budget(&fat);
        let b = small.clip_into_budget(&fat);
        assert_eq!(a.foldings, b.foldings, "clip must be deterministic");
        assert!(
            small.resources(&a).fits_in(&small.budget),
            "minimal mapping fits 25% of the board, so the clip must too"
        );
        // A mapping already inside the budget is returned untouched.
        let inside = small.clip_into_budget(&small.mapping);
        assert_eq!(inside.foldings, small.mapping.foldings);
    }

    #[test]
    fn tiny_budget_infeasible() {
        let net = testnet::blenet_like();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            ResourceVec::new(100, 100, 1, 1),
            125e6,
        );
        assert!(!p.feasible(&p.mapping));
    }
}
