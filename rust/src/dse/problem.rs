//! DSE problem definition: which CDFG nodes are being folded, what counts
//! against the budget, and what II is being minimized.
//!
//! The paper generates *separate* TAP functions for each stage of the EE
//! network (§III-A) by giving the optimizer "limited fractions of the
//! board resource constraints". A `Problem` captures one such sub-design:
//! the baseline backbone, or EE pipeline section `i` — its backbone
//! nodes, its exit branch (when it has one), and (for section 0, the
//! full-rate front) the Egress. The number of sections is data, not part
//! of the type.

use crate::ir::{Cdfg, StageId};
use crate::resources::{model, ResourceVec};
use crate::sdf::HwMapping;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProblemKind {
    /// Single-stage baseline network (whole backbone, full rate).
    Baseline,
    /// EE pipeline section `i`: `Stage(0)` is the paper's stage 1
    /// (everything at the input sample rate), `Stage(i)` for `i > 0` the
    /// section behind Conditional Buffer `i - 1`.
    Stage(usize),
}

/// What the search optimizes (the paper reports both headline shapes:
/// maximum throughput under a budget, Fig. 9's speedup claim, and the
/// cheapest design matching a throughput target, the "46% of the
/// resources" claim).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Objective {
    /// Maximize throughput under the problem's resource budget — the
    /// original (and default) mode.
    MaxThroughput,
    /// Minimize the scalar area norm
    /// ([`ResourceVec::utilization`](crate::resources::ResourceVec::utilization)
    /// against the budget) subject to throughput ≥ the target in
    /// samples/s. The annealer's energy trades area for a throughput
    /// shortfall penalty; `dse::pareto::min_area_design` wraps this with
    /// a frontier fallback so the outcome is never worse than the best
    /// swept point.
    MinAreaAtThroughput(f64),
    /// Trace the whole throughput/area frontier. A single anneal under
    /// this objective is **bit-identical** to [`Objective::MaxThroughput`]
    /// (the frontier mode is a sweep of per-budget max-throughput
    /// searches — `dse::pareto::sweep_frontier` supplies the budget
    /// ladder; property-tested in `tests/pareto_props.rs`).
    ParetoFront,
}

/// One DSE instance over a node subset of a mapping.
#[derive(Clone, Debug)]
pub struct Problem {
    pub kind: ProblemKind,
    pub mapping: HwMapping,
    /// Node ids whose folding the search mutates and whose resources are
    /// charged against the budget.
    pub active: Vec<usize>,
    pub budget: ResourceVec,
    pub clock_hz: f64,
    /// What the annealer's energy rewards (default
    /// [`Objective::MaxThroughput`]).
    pub objective: Objective,
}

impl Problem {
    pub fn baseline(cdfg: Cdfg, budget: ResourceVec, clock_hz: f64) -> Problem {
        let mapping = HwMapping::minimal(cdfg);
        let active = (0..mapping.cdfg.nodes.len()).collect();
        Problem {
            kind: ProblemKind::Baseline,
            mapping,
            active,
            budget,
            clock_hz,
            objective: Objective::MaxThroughput,
        }
    }

    /// The DSE problem for EE pipeline section `sec`: its backbone
    /// nodes and exit branch, plus the Egress for the full-rate front
    /// (section 0).
    pub fn stage(sec: usize, cdfg: Cdfg, budget: ResourceVec, clock_hz: f64) -> Problem {
        let mapping = HwMapping::minimal(cdfg);
        let active = mapping
            .cdfg
            .nodes
            .iter()
            .filter(|n| match n.stage {
                StageId::Backbone(i) | StageId::ExitBranch(i) => i == sec,
                StageId::Egress => sec == 0,
            })
            .map(|n| n.id)
            .collect();
        Problem {
            kind: ProblemKind::Stage(sec),
            mapping,
            active,
            budget,
            clock_hz,
            objective: Objective::MaxThroughput,
        }
    }

    /// Build a problem for a planned sweep kind.
    pub fn for_kind(kind: ProblemKind, cdfg: Cdfg, budget: ResourceVec, clock_hz: f64) -> Problem {
        match kind {
            ProblemKind::Baseline => Problem::baseline(cdfg, budget, clock_hz),
            ProblemKind::Stage(sec) => Problem::stage(sec, cdfg, budget, clock_hz),
        }
    }

    /// Replace the search objective (builder-style; constructors default
    /// to [`Objective::MaxThroughput`]).
    pub fn with_objective(mut self, objective: Objective) -> Problem {
        self.objective = objective;
        self
    }

    /// Whether this problem kind hosts the shared I/O infrastructure.
    /// It is charged to Baseline and to Stage(0) (which own the I/O
    /// path); later sections' shares arrive via the TAP combination's
    /// shared-budget form.
    pub fn charges_infrastructure(kind: ProblemKind) -> bool {
        matches!(kind, ProblemKind::Baseline | ProblemKind::Stage(0))
    }

    /// II being minimized: max over the active nodes.
    pub fn ii(&self, mapping: &HwMapping) -> u64 {
        self.active
            .iter()
            .map(|&id| mapping.node_ii(id))
            .max()
            .unwrap_or(1)
    }

    /// Resources charged to this problem (see
    /// [`Problem::charges_infrastructure`]).
    pub fn resources(&self, mapping: &HwMapping) -> ResourceVec {
        let mut total = if Self::charges_infrastructure(self.kind) {
            model::infrastructure()
        } else {
            ResourceVec::ZERO
        };
        for &id in &self.active {
            total += mapping.node_resources(id);
        }
        total
    }

    pub fn feasible(&self, mapping: &HwMapping) -> bool {
        self.resources(mapping).fits_in(&self.budget)
    }

    /// Throughput at the nominal (unscaled) rate for a mapping.
    pub fn throughput(&self, mapping: &HwMapping) -> f64 {
        self.clock_hz / self.ii(mapping) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;
    use crate::resources::Board;

    #[test]
    fn stage_problems_partition_std_nodes() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cdfg = Cdfg::lower(&net, 8);
        let p1 = Problem::stage(0, cdfg.clone(), board.resources, board.clock_hz);
        let p2 = Problem::stage(1, cdfg.clone(), board.resources, board.clock_hz);
        // Disjoint and jointly exhaustive over the CDFG.
        for id in &p1.active {
            assert!(!p2.active.contains(id));
        }
        assert_eq!(p1.active.len() + p2.active.len(), cdfg.nodes.len());
    }

    #[test]
    fn three_exit_stage_problems_partition() {
        let net = testnet::three_exit();
        let board = Board::zc706();
        let cdfg = Cdfg::lower(&net, 4);
        let probs: Vec<Problem> = (0..cdfg.n_sections)
            .map(|i| Problem::stage(i, cdfg.clone(), board.resources, board.clock_hz))
            .collect();
        let total: usize = probs.iter().map(|p| p.active.len()).sum();
        assert_eq!(total, cdfg.nodes.len());
        for (i, a) in probs.iter().enumerate() {
            for b in probs.iter().skip(i + 1) {
                for id in &a.active {
                    assert!(!b.active.contains(id), "node {id} owned by two stages");
                }
            }
        }
        // Infrastructure: charged exactly to baseline and section 0.
        assert!(Problem::charges_infrastructure(ProblemKind::Baseline));
        assert!(Problem::charges_infrastructure(ProblemKind::Stage(0)));
        assert!(!Problem::charges_infrastructure(ProblemKind::Stage(1)));
        assert!(!Problem::charges_infrastructure(ProblemKind::Stage(2)));
    }

    #[test]
    fn minimal_mapping_feasible_on_board() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.resources,
            board.clock_hz,
        );
        assert!(p.feasible(&p.mapping));
        assert!(p.throughput(&p.mapping) > 0.0);
    }

    #[test]
    fn tiny_budget_infeasible() {
        let net = testnet::blenet_like();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            ResourceVec::new(100, 100, 1, 1),
            125e6,
        );
        assert!(!p.feasible(&p.mapping));
    }
}
