//! Resource-budget exploration: the throughput/area Pareto frontier and
//! the area-minimizing search behind the paper's second headline claim
//! ("ATHEENA matches the baseline's throughput with as low as 46% of
//! its resources", Fig. 9/10's resource-matched operating points).
//!
//! A frontier is traced by sweeping budget *scalings* of a board and
//! keeping the non-dominated (throughput, area-norm) points, where the
//! area norm is the scalar [`ResourceVec::utilization`] against the
//! *full* board. Since PR 8 the ladder is **incremental** (DESIGN.md
//! §11): [`sweep_frontier`] visits rungs in descending budget order in
//! independent chains (wave-scheduled on `util::exec::run_ordered`),
//! cold-annealing each chain's anchor and seeding every other rung from
//! its neighbour's result clipped into the smaller budget
//! ([`Problem::clip_into_budget`] → [`anneal_seeded`]). The cold
//! one-full-[`anneal`]-per-rung ladder survives as
//! [`sweep_frontier_sequential`], the reference oracle; the warm
//! frontier is property-tested to never be dominated by it at any
//! budget point. After the dominance filter the frontier is strictly
//! monotone in **both** axes (property-tested in
//! `tests/pareto_props.rs`).
//!
//! [`Objective`](super::Objective) ties the three search modes
//! together: `MaxThroughput` is one ladder rung, `ParetoFront` is the
//! whole ladder (a single-rung ladder degenerates bit-identically to
//! `MaxThroughput`), and `MinAreaAtThroughput` is answered from the
//! frontier plus an objective-aware refinement anneal whose result is
//! only kept when it strictly improves on the best swept point — so
//! [`min_area_design`] is never beaten by any frontier point of lower
//! area.

use super::annealer::{anneal, anneal_seeded, AnnealConfig, AnnealResult};
use super::problem::{Objective, Problem, ProblemKind};
use super::sweep::{plan_sweep, SweepConfig, SweepTask};
use crate::ir::Cdfg;
use crate::resources::{Board, ResourceVec};
use crate::sdf::HwMapping;
use crate::util::Json;

/// Warm-start chaining parameters for the incremental budget ladder
/// (DESIGN.md §11). Rungs are swept in descending budget order in
/// chains of `chain_len`; each chain's first rung ("anchor") is a full
/// cold anneal — bit-identical to the cold ladder's rung, same task
/// seed — and each subsequent rung is seeded from its neighbour's
/// result clipped into the smaller budget
/// ([`Problem::clip_into_budget`]) via
/// [`anneal_seeded`](super::annealer::anneal_seeded) with `restarts`
/// restarts. Interior rungs doing less restart work than the cold
/// ladder is where the `warm_speedup` comes from; the clipped seed
/// recorded as the initial best is the exact floor the
/// never-dominated-by-cold property stands on.
#[derive(Clone, Debug)]
pub struct WarmStart {
    /// Rungs per independent chain (wave scheduling: chains run in
    /// parallel on the deterministic executor, rungs within a chain are
    /// sequential because each seeds the next). `1` degenerates every
    /// rung to a cold anchor — the cold ladder exactly.
    pub chain_len: usize,
    /// Restarts for warm-seeded (non-anchor) rungs. Restart 0 runs from
    /// the clipped seed; restarts ≥ 1 replay the cold anneal's restart
    /// streams bit for bit (diversification escape hatch).
    pub restarts: usize,
}

impl Default for WarmStart {
    fn default() -> Self {
        WarmStart {
            chain_len: 5,
            restarts: 1,
        }
    }
}

/// Budget-scaling ladder + anneal schedule for a frontier sweep.
#[derive(Clone, Debug)]
pub struct ParetoConfig {
    /// Board-budget scalings to constrain the optimizer at, one anneal
    /// per entry (seed derived per index, exactly like a TAP sweep).
    pub scalings: Vec<f64>,
    pub anneal: AnnealConfig,
    /// Warm-start chaining for [`sweep_frontier`]; the cold reference
    /// [`sweep_frontier_sequential`] ignores it.
    pub warm: WarmStart,
}

impl Default for ParetoConfig {
    fn default() -> Self {
        ParetoConfig {
            scalings: SweepConfig::default().fractions,
            anneal: AnnealConfig::default(),
            warm: WarmStart::default(),
        }
    }
}

impl ParetoConfig {
    /// Faster ladder for tests and smoke runs.
    pub fn quick() -> ParetoConfig {
        ParetoConfig {
            scalings: SweepConfig::quick().fractions,
            anneal: AnnealConfig::quick(),
            warm: WarmStart::default(),
        }
    }
}

/// One non-dominated operating point of a throughput/area frontier.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FrontierPoint {
    /// Board-budget scaling the optimizer was constrained to.
    pub budget_fraction: f64,
    pub ii: u64,
    /// Throughput in samples/s (nominal for a single problem kind,
    /// at-design-reach for a combined EE design).
    pub throughput: f64,
    pub resources: ResourceVec,
    /// Scalar area norm: [`ResourceVec::utilization`] against the full
    /// board — the frontier's area axis.
    pub utilization: f64,
    /// Index into the originating design list / raw sweep results.
    pub source: usize,
    /// Certified optimality gap in percent (`atheena pareto
    /// --certify`, DESIGN.md §13): how far this point's heuristic
    /// design sits from the exact branch-and-bound optimum at its
    /// budget. `None` until certification runs (or when the point's
    /// problem exceeds the exact-size budget) — uncertified artifacts
    /// round-trip unchanged, byte for byte.
    pub gap_pct: Option<f64>,
}

impl FrontierPoint {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("budget_fraction", Json::Num(self.budget_fraction)),
            ("ii", Json::num(self.ii as f64)),
            ("throughput", Json::Num(self.throughput)),
            ("resources", self.resources.to_json()),
            ("utilization", Json::Num(self.utilization)),
            ("source", Json::num(self.source as f64)),
        ];
        if let Some(gap) = self.gap_pct {
            // Serialized only when present: schema-v5 artifacts without
            // certification stay byte-identical to their v4 bodies.
            fields.push(("gap_pct", Json::Num(gap)));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<FrontierPoint> {
        let num = |k: &str| -> anyhow::Result<f64> {
            v.req(k)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("frontier point '{k}' must be a number"))
        };
        Ok(FrontierPoint {
            budget_fraction: num("budget_fraction")?,
            ii: num("ii")? as u64,
            throughput: num("throughput")?,
            resources: ResourceVec::from_json(v.req("resources")?)?,
            utilization: num("utilization")?,
            source: num("source")? as usize,
            gap_pct: v.get("gap_pct").and_then(|g| g.as_f64()),
        })
    }
}

/// A throughput/area Pareto frontier: mutually non-dominated points,
/// strictly increasing in both utilization and throughput.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ParetoFrontier {
    pub points: Vec<FrontierPoint>,
}

impl ParetoFrontier {
    /// Dominance-filter raw points. Point `a` dominates `b` iff
    /// `a.throughput >= b.throughput` and `a.utilization <=
    /// b.utilization` (duplicates collapse to one). The survivors are
    /// sorted ascending in utilization, which — dominance-freeness —
    /// makes them strictly ascending in throughput too.
    pub fn from_points(mut raw: Vec<FrontierPoint>) -> ParetoFrontier {
        raw.sort_by(|a, b| {
            a.throughput
                .total_cmp(&b.throughput)
                .then(b.utilization.total_cmp(&a.utilization))
        });
        let mut keep: Vec<FrontierPoint> = Vec::new();
        for p in raw {
            keep.retain(|q| {
                !(p.throughput >= q.throughput && p.utilization <= q.utilization)
            });
            let dominated = keep
                .iter()
                .any(|q| q.throughput >= p.throughput && q.utilization <= p.utilization);
            if !dominated {
                keep.push(p);
            }
        }
        keep.sort_by(|a, b| a.utilization.total_cmp(&b.utilization));
        ParetoFrontier { points: keep }
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// The cheapest frontier point meeting `target` samples/s — the
    /// resource-matched lookup. `None` when even the fastest point
    /// misses the target.
    pub fn min_area_at(&self, target: f64) -> Option<&FrontierPoint> {
        self.points.iter().find(|p| p.throughput >= target)
    }

    /// The fastest point (the frontier's max-throughput end).
    pub fn best_throughput(&self) -> Option<&FrontierPoint> {
        self.points.last()
    }

    pub fn to_json(&self) -> Json {
        Json::arr(self.points.iter().map(|p| p.to_json()))
    }

    /// Load a frontier back. Stored points already went through the
    /// dominance filter, so they are taken verbatim (re-filtering would
    /// be a no-op but could reorder exact ties).
    pub fn from_json(v: &Json) -> anyhow::Result<ParetoFrontier> {
        let points = v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("frontier must be an array"))?
            .iter()
            .map(FrontierPoint::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(ParetoFrontier { points })
    }
}

/// Plan a frontier sweep: one anneal task per budget scaling, seeds
/// derived per index with the same `seed + i * 7919` scheme as the TAP
/// sweeps (so a single-scaling ladder reproduces a direct anneal bit
/// for bit).
pub fn plan_frontier(
    kind: ProblemKind,
    cdfg: &Cdfg,
    board: &Board,
    cfg: &ParetoConfig,
) -> Vec<SweepTask> {
    plan_sweep(
        kind,
        cdfg,
        board,
        &SweepConfig {
            fractions: cfg.scalings.clone(),
            anneal: cfg.anneal.clone(),
        },
    )
}

/// Turn per-scaling anneal results (in ladder order) into a frontier:
/// feasible results only, area-normed against the full board, then
/// dominance-filtered. `scalings[i]` is the budget scaling result `i`
/// was annealed under. Errors (in every build profile) when the two
/// slices disagree in length — a malformed sweep must not silently
/// mis-attribute budget fractions.
pub fn assemble_frontier(
    board: &Board,
    scalings: &[f64],
    results: &[AnnealResult],
) -> anyhow::Result<ParetoFrontier> {
    anyhow::ensure!(
        scalings.len() == results.len(),
        "frontier assembly: {} scalings vs {} anneal results",
        scalings.len(),
        results.len()
    );
    let raw = results
        .iter()
        .enumerate()
        .filter(|(_, r)| r.feasible)
        .map(|(i, r)| FrontierPoint {
            budget_fraction: scalings[i],
            ii: r.ii,
            throughput: r.throughput,
            resources: r.resources,
            utilization: r.resources.utilization(&board.resources),
            source: i,
            gap_pct: None,
        })
        .collect::<Vec<_>>();
    Ok(ParetoFrontier::from_points(raw))
}

/// Sweep the budget-scaling ladder **incrementally** and extract the
/// frontier. Returns the frontier plus every raw anneal result in
/// ladder order (frontier points link back via `source`).
///
/// Rungs are visited in descending budget order in chains of
/// `cfg.warm.chain_len` (independent chains run in parallel on the
/// deterministic executor — wave scheduling). Each chain's anchor rung
/// is a full cold [`anneal`] — bit-identical to the same rung of the
/// cold [`sweep_frontier_sequential`] ladder, same per-index task seed
/// — and every subsequent rung seeds [`anneal_seeded`] with the
/// neighbour's result clipped into the smaller budget. Warm-start is a
/// deterministic *seed* change, never a silent result change: the
/// quality gate (`tests/pareto_props.rs`) checks the warm frontier is
/// never dominated by the cold frontier at any budget point.
pub fn sweep_frontier(
    kind: ProblemKind,
    cdfg: &Cdfg,
    board: &Board,
    cfg: &ParetoConfig,
) -> anyhow::Result<(ParetoFrontier, Vec<AnnealResult>)> {
    let tasks = plan_frontier(kind, cdfg, board, cfg);
    // Descending budget order (ties: ladder index) — chains seed
    // downward into tighter budgets, where a clipped good design is a
    // meaningful floor.
    let mut order: Vec<usize> = (0..tasks.len()).collect();
    order.sort_by(|&a, &b| cfg.scalings[b].total_cmp(&cfg.scalings[a]).then(a.cmp(&b)));
    let chains: Vec<&[usize]> = order.chunks(cfg.warm.chain_len.max(1)).collect();
    let per_chain: Vec<Vec<(usize, AnnealResult)>> =
        crate::util::exec::run_ordered(chains.len(), |c| {
            let mut out = Vec::with_capacity(chains[c].len());
            let mut prev: Option<HwMapping> = None;
            for &i in chains[c] {
                let task = &tasks[i];
                let r = match &prev {
                    None => anneal(&task.problem, &task.config),
                    Some(neighbour) => {
                        let clipped = task.problem.clip_into_budget(neighbour);
                        let mut wcfg = task.config.clone();
                        wcfg.restarts = cfg.warm.restarts.max(1);
                        anneal_seeded(&task.problem, &wcfg, &clipped)
                    }
                };
                prev = Some(r.mapping.clone());
                out.push((i, r));
            }
            out
        });
    let mut slots: Vec<Option<AnnealResult>> = vec![None; tasks.len()];
    for chain in per_chain {
        for (i, r) in chain {
            slots[i] = Some(r);
        }
    }
    let results: Vec<AnnealResult> = slots
        .into_iter()
        .map(|r| r.ok_or_else(|| anyhow::anyhow!("a ladder rung was never annealed")))
        .collect::<anyhow::Result<_>>()?;
    Ok((assemble_frontier(board, &cfg.scalings, &results)?, results))
}

/// Sequential **cold** reference path for [`sweep_frontier`] — one full
/// cold anneal per rung in ladder order, no warm-start chaining (the
/// repo-idiom oracle, cf. `anneal_sequential`). The warm sweep's
/// quality gate compares against this ladder.
pub fn sweep_frontier_sequential(
    kind: ProblemKind,
    cdfg: &Cdfg,
    board: &Board,
    cfg: &ParetoConfig,
) -> anyhow::Result<(ParetoFrontier, Vec<AnnealResult>)> {
    let tasks = plan_frontier(kind, cdfg, board, cfg);
    let results: Vec<AnnealResult> = tasks
        .iter()
        .map(|t| anneal(&t.problem, &t.config))
        .collect();
    Ok((assemble_frontier(board, &cfg.scalings, &results)?, results))
}

/// A single-design outcome of an objective search ([`min_area_design`]
/// or `solve(MaxThroughput)`), with its frontier context.
#[derive(Clone, Debug)]
pub struct ObjectiveOutcome {
    /// The chosen design (mapping, II, resources).
    pub result: AnnealResult,
    /// Its scalar area norm against the full board.
    pub utilization: f64,
    /// The budget scaling the design was found under.
    pub budget_fraction: f64,
    /// The frontier the pick came from (for reporting).
    pub frontier: ParetoFrontier,
}

/// Find the cheapest design meeting `target` samples/s: sweep the
/// frontier, take the cheapest point that meets the target, then run
/// one objective-aware refinement anneal
/// ([`Objective::MinAreaAtThroughput`]) at that point's budget and keep
/// the refined design only when it meets the target, fits, and
/// **strictly** lowers the area norm. When the problem fits the
/// exact-size budget a final seeded branch-and-bound polish
/// ([`exact_seeded`](super::exact::exact_seeded)) replaces the
/// heuristic pick with the *provably* area-minimal design at that
/// budget. By construction the outcome is never beaten by a frontier
/// point of lower area (property-tested in `tests/pareto_props.rs`).
pub fn min_area_design(
    kind: ProblemKind,
    cdfg: &Cdfg,
    board: &Board,
    cfg: &ParetoConfig,
    target: f64,
) -> anyhow::Result<ObjectiveOutcome> {
    anyhow::ensure!(
        target.is_finite() && target > 0.0,
        "throughput target must be finite and positive, got {target}"
    );
    let (frontier, results) = sweep_frontier(kind, cdfg, board, cfg)?;
    let picked = frontier.min_area_at(target).copied().ok_or_else(|| {
        anyhow::anyhow!(
            "no swept design reaches {target:.0} samples/s (frontier max {:.0})",
            frontier
                .best_throughput()
                .map(|p| p.throughput)
                .unwrap_or(0.0)
        )
    })?;
    let mut outcome = ObjectiveOutcome {
        result: results[picked.source].clone(),
        utilization: picked.utilization,
        budget_fraction: picked.budget_fraction,
        frontier,
    };

    // Refinement: an area-minimizing anneal at the picked budget. The
    // seed is decorrelated from the ladder's so the refinement explores
    // fresh trajectories.
    let budget = board.budget(picked.budget_fraction);
    let problem = Problem::for_kind(kind, cdfg.clone(), budget, board.clock_hz)
        .with_objective(Objective::MinAreaAtThroughput(target));
    let mut rcfg = cfg.anneal.clone();
    rcfg.seed = rcfg.seed.wrapping_mul(0x9E37_79B9).wrapping_add(0x4A3E);
    let refined = anneal(&problem, &rcfg);
    if refined.feasible && refined.throughput >= target {
        let u = refined.resources.utilization(&board.resources);
        if u < outcome.utilization {
            outcome.result = refined;
            outcome.utilization = u;
        }
    }

    // Exact polish: seed the branch-and-bound oracle with the best
    // heuristic value so far; if a provably smaller qualifying design
    // exists within the size budget, take it. `polish()` keeps the
    // worst-case visit count small enough for the inline pipeline path;
    // oversized problems fall through with the heuristic pick intact.
    let seed_util = outcome
        .result
        .resources
        .max_utilisation(&problem.budget);
    if let super::exact::SeededOutcome::Better(r) = super::exact::exact_seeded(
        &problem,
        &super::exact::ExactConfig::polish(),
        outcome.result.ii,
        seed_util,
    ) {
        let u = r.resources.utilization(&board.resources);
        if u < outcome.utilization {
            outcome.result = AnnealResult {
                throughput: r.throughput,
                ii: r.ii,
                resources: r.resources,
                mapping: r.mapping,
                feasible: true,
                iterations_run: outcome.result.iterations_run,
                accepted: outcome.result.accepted,
            };
            outcome.utilization = u;
        }
    }
    Ok(outcome)
}

/// How a solved objective comes back from [`solve`].
#[derive(Clone, Debug)]
pub enum Solution {
    /// A single design (`MaxThroughput`, `MinAreaAtThroughput`).
    Design(Box<ObjectiveOutcome>),
    /// The whole frontier (`ParetoFront`).
    Front(ParetoFrontier),
}

/// Dispatch an [`Objective`] over one problem kind.
///
/// * `MaxThroughput` — one anneal at the ladder's last scaling (the
///   full budget in the default ladder), seeded like that ladder rung,
///   so `solve(ParetoFront)` over a single-scaling ladder contains the
///   bit-identical point.
/// * `MinAreaAtThroughput` — [`min_area_design`].
/// * `ParetoFront` — [`sweep_frontier`].
pub fn solve(
    objective: Objective,
    kind: ProblemKind,
    cdfg: &Cdfg,
    board: &Board,
    cfg: &ParetoConfig,
) -> anyhow::Result<Solution> {
    match objective {
        Objective::MaxThroughput => {
            anyhow::ensure!(!cfg.scalings.is_empty(), "empty budget ladder");
            let frac = *cfg.scalings.last().unwrap();
            let tasks = plan_frontier(kind, cdfg, board, cfg);
            let task = tasks.last().unwrap();
            let r = anneal(&task.problem, &task.config);
            anyhow::ensure!(r.feasible, "no feasible design at budget {frac}");
            let utilization = r.resources.utilization(&board.resources);
            Ok(Solution::Design(Box::new(ObjectiveOutcome {
                utilization,
                budget_fraction: frac,
                frontier: assemble_frontier(
                    board,
                    &cfg.scalings[cfg.scalings.len() - 1..],
                    std::slice::from_ref(&r),
                )?,
                result: r,
            })))
        }
        Objective::MinAreaAtThroughput(target) => Ok(Solution::Design(Box::new(
            min_area_design(kind, cdfg, board, cfg, target)?,
        ))),
        Objective::ParetoFront => {
            Ok(Solution::Front(sweep_frontier(kind, cdfg, board, cfg)?.0))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;

    fn pt(thr: f64, util: f64) -> FrontierPoint {
        FrontierPoint {
            budget_fraction: util,
            ii: 1,
            throughput: thr,
            resources: ResourceVec::new(
                (util * 1000.0) as u64,
                (util * 2000.0) as u64,
                (util * 100.0) as u64,
                (util * 100.0) as u64,
            ),
            utilization: util,
            source: 0,
            gap_pct: None,
        }
    }

    #[test]
    fn dominance_filter_keeps_monotone_front() {
        let front = ParetoFrontier::from_points(vec![
            pt(100.0, 0.5), // dominated by (120, 0.4)
            pt(120.0, 0.4),
            pt(80.0, 0.2),
            pt(200.0, 0.9),
            pt(120.0, 0.6), // dominated (same thr, more area)
        ]);
        assert_eq!(front.len(), 3);
        for w in front.points.windows(2) {
            assert!(w[1].utilization > w[0].utilization);
            assert!(w[1].throughput > w[0].throughput);
        }
    }

    #[test]
    fn duplicate_points_collapse() {
        let front =
            ParetoFrontier::from_points(vec![pt(100.0, 0.5), pt(100.0, 0.5)]);
        assert_eq!(front.len(), 1);
    }

    #[test]
    fn min_area_lookup_picks_cheapest_meeting_target() {
        let front = ParetoFrontier::from_points(vec![
            pt(80.0, 0.2),
            pt(120.0, 0.4),
            pt(200.0, 0.9),
        ]);
        assert_eq!(front.min_area_at(100.0).unwrap().utilization, 0.4);
        assert_eq!(front.min_area_at(50.0).unwrap().utilization, 0.2);
        assert!(front.min_area_at(300.0).is_none());
        assert_eq!(front.best_throughput().unwrap().throughput, 200.0);
    }

    #[test]
    fn frontier_json_roundtrip() {
        let front = ParetoFrontier::from_points(vec![
            pt(80.0, 0.2),
            pt(120.0, 0.4),
            pt(200.0, 0.9),
        ]);
        let back = ParetoFrontier::from_json(&front.to_json()).unwrap();
        assert_eq!(back, front);
    }

    #[test]
    fn empty_ladder_sweeps_to_empty_frontier() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cfg = ParetoConfig {
            scalings: vec![],
            ..ParetoConfig::quick()
        };
        let cdfg = Cdfg::lower_baseline(&net);
        let (front, raw) =
            sweep_frontier(ProblemKind::Baseline, &cdfg, &board, &cfg).unwrap();
        assert!(front.is_empty());
        assert!(raw.is_empty());
        let (cold, _) =
            sweep_frontier_sequential(ProblemKind::Baseline, &cdfg, &board, &cfg).unwrap();
        assert!(cold.is_empty());
    }

    #[test]
    fn all_infeasible_ladder_gives_empty_frontier() {
        // Budget scalings so small even the minimal mapping (plus
        // infrastructure) overflows: every rung reports infeasible and
        // the frontier is empty rather than an error.
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cfg = ParetoConfig {
            scalings: vec![1e-6, 2e-6],
            anneal: AnnealConfig {
                iterations: 50,
                restarts: 1,
                ..Default::default()
            },
            ..ParetoConfig::quick()
        };
        let cdfg = Cdfg::lower_baseline(&net);
        let (front, raw) =
            sweep_frontier(ProblemKind::Baseline, &cdfg, &board, &cfg).unwrap();
        assert!(raw.iter().all(|r| !r.feasible));
        assert!(front.is_empty());
    }

    #[test]
    fn single_scaling_ladder_gives_single_point_frontier() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cfg = ParetoConfig {
            scalings: vec![1.0],
            ..ParetoConfig::quick()
        };
        let cdfg = Cdfg::lower_baseline(&net);
        let (front, raw) =
            sweep_frontier(ProblemKind::Baseline, &cdfg, &board, &cfg).unwrap();
        assert_eq!(raw.len(), 1);
        assert_eq!(front.len(), 1);
        assert_eq!(front.points[0].source, 0);
    }

    #[test]
    fn assemble_frontier_length_mismatch_errors_in_release_too() {
        let board = Board::zc706();
        let err = assemble_frontier(&board, &[0.5, 1.0], &[]).unwrap_err();
        assert!(err.to_string().contains("2 scalings vs 0"));
        assert!(assemble_frontier(&board, &[], &[]).unwrap().is_empty());
    }

    #[test]
    fn frontier_sweep_on_testnet_is_monotone() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cfg = ParetoConfig::quick();
        let cdfg = Cdfg::lower_baseline(&net);
        let (front, raw) =
            sweep_frontier(ProblemKind::Baseline, &cdfg, &board, &cfg).unwrap();
        assert!(!front.is_empty());
        assert_eq!(raw.len(), cfg.scalings.len());
        for w in front.points.windows(2) {
            assert!(w[1].throughput > w[0].throughput);
            assert!(w[1].utilization > w[0].utilization);
        }
        for p in &front.points {
            assert!(p.utilization <= 1.0 + 1e-12);
            assert!(raw[p.source].feasible);
            assert_eq!(raw[p.source].resources, p.resources);
            assert!(cfg.scalings.contains(&p.budget_fraction));
        }
    }
}
