//! Alternative search / allocation strategies — ablation baselines.
//!
//! The paper motivates both of its choices implicitly:
//!
//! * §III: "A naive implementation would have all stages of the network
//!   optimized for the highest possible throughput. However, in the
//!   presence of any resource constraints this is clearly a sub-optimal
//!   strategy" — the **naive allocator** here implements exactly that
//!   strawman (optimize both stages at the full budget, then scale both
//!   down uniformly until the pair fits), so the report can quantify what
//!   Eq. 1 buys.
//! * fpgaConvNet chose simulated annealing for the folding search; the
//!   **greedy** and **random-search** optimizers here provide the
//!   comparison points for that choice (`atheena report` ablation +
//!   `benches/bench_ablation.rs`).

use super::annealer::{AnnealConfig, AnnealResult};
use super::problem::Problem;
use crate::resources::ResourceVec;
use crate::sdf::folding::FoldingSpace;
use crate::tap::{CombinedDesign, TapCurve};
use crate::util::Rng;

/// Greedy hill-climb: repeatedly take the single folding step (over all
/// nodes and axes) with the best II improvement per additional limiting
/// resource, until nothing fits. Deterministic.
pub fn greedy(problem: &Problem) -> AnnealResult {
    let mut mapping = problem.mapping.clone();
    let mut iterations = 0usize;
    loop {
        iterations += 1;
        let cur_ii = problem.ii(&mapping);
        let mut best: Option<(f64, usize, crate::sdf::Folding)> = None;
        for &id in &problem.active {
            let space = &mapping.spaces[id];
            let cur = mapping.foldings[id];
            let candidates = [
                FoldingSpace::step(&space.coarse_in, cur.coarse_in, true)
                    .map(|v| crate::sdf::Folding { coarse_in: v, ..cur }),
                FoldingSpace::step(&space.coarse_out, cur.coarse_out, true)
                    .map(|v| crate::sdf::Folding { coarse_out: v, ..cur }),
                FoldingSpace::step(&space.fine, cur.fine, true)
                    .map(|v| crate::sdf::Folding { fine: v, ..cur }),
            ];
            for cand in candidates.into_iter().flatten() {
                let prev = mapping.foldings[id];
                mapping.foldings[id] = cand;
                let ii = problem.ii(&mapping);
                let feasible = problem.feasible(&mapping);
                let res = problem.resources(&mapping);
                mapping.foldings[id] = prev;
                if !feasible || ii >= cur_ii {
                    continue;
                }
                // Improvement density: II gain per marginal utilisation.
                let util = res.max_utilisation(&problem.budget).max(1e-9);
                let score = (cur_ii - ii) as f64 / util;
                if best.as_ref().map(|(s, _, _)| score > *s).unwrap_or(true) {
                    best = Some((score, id, cand));
                }
            }
        }
        match best {
            Some((_, id, f)) => mapping.foldings[id] = f,
            None => break,
        }
    }
    let ii = problem.ii(&mapping);
    AnnealResult {
        throughput: problem.clock_hz / ii as f64,
        resources: problem.resources(&mapping),
        feasible: problem.feasible(&mapping),
        ii,
        mapping,
        iterations_run: iterations,
        accepted: 0,
    }
}

/// Pure random search with the same evaluation budget as the annealer:
/// sample random feasible folding assignments, keep the best.
pub fn random_search(problem: &Problem, cfg: &AnnealConfig) -> AnnealResult {
    let mut rng = Rng::new(cfg.seed);
    let evals = cfg.iterations * cfg.restarts;
    let mut best: Option<(u64, crate::sdf::HwMapping)> = None;
    let mut mapping = problem.mapping.clone();
    for _ in 0..evals {
        for &id in &problem.active {
            let space = &mapping.spaces[id];
            mapping.foldings[id] = crate::sdf::Folding {
                coarse_in: *rng.choose(&space.coarse_in),
                coarse_out: *rng.choose(&space.coarse_out),
                fine: *rng.choose(&space.fine),
            };
        }
        if !problem.feasible(&mapping) {
            continue;
        }
        let ii = problem.ii(&mapping);
        if best.as_ref().map(|(b, _)| ii < *b).unwrap_or(true) {
            best = Some((ii, mapping.clone()));
        }
    }
    let (ii, mapping) = best.unwrap_or_else(|| {
        let m = problem.mapping.clone();
        (problem.ii(&m), m)
    });
    AnnealResult {
        throughput: problem.clock_hz / ii as f64,
        resources: problem.resources(&mapping),
        feasible: problem.feasible(&mapping),
        ii,
        mapping,
        iterations_run: evals,
        accepted: 0,
    }
}

/// The §III strawman: allocate *both* stages their individually-best
/// design at the full budget (highest possible throughput each), then
/// walk both down the Pareto curves in lockstep until the pair fits the
/// combined budget. No probability-aware 1/p scaling.
pub fn naive_combine(
    f: &TapCurve,
    g: &TapCurve,
    budget: &ResourceVec,
) -> Option<CombinedDesign> {
    let mut i = f.points.len();
    let mut j = g.points.len();
    while i > 0 && j > 0 {
        let s1 = &f.points[i - 1];
        let s2 = &g.points[j - 1];
        if (s1.resources + s2.resources).fits_in(budget) {
            return Some(CombinedDesign {
                stage1: *s1,
                stage2: *s2,
                p: 1.0, // the naive strategy ignores p
                throughput_at_p: s1.throughput.min(s2.throughput),
            });
        }
        // Step down whichever stage currently spends more of the budget's
        // scarcest resource.
        let u1 = s1.resources.max_utilisation(budget);
        let u2 = s2.resources.max_utilisation(budget);
        if u1 >= u2 {
            i -= 1;
        } else {
            j -= 1;
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::annealer::anneal;
    use crate::dse::problem::Problem;
    use crate::ir::network::testnet;
    use crate::ir::Cdfg;
    use crate::resources::Board;
    use crate::tap::{combine, TapPoint};

    fn problem(frac: f64) -> Problem {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.budget(frac),
            board.clock_hz,
        )
    }

    #[test]
    fn greedy_finds_feasible_fast_design() {
        let p = problem(0.5);
        let r = greedy(&p);
        assert!(r.feasible);
        assert!(r.throughput > p.throughput(&p.mapping) * 5.0);
    }

    #[test]
    fn annealer_at_least_matches_greedy_and_random() {
        let p = problem(0.4);
        let cfg = AnnealConfig::default();
        let sa = anneal(&p, &cfg);
        let gr = greedy(&p);
        let rs = random_search(&p, &AnnealConfig::quick());
        assert!(
            sa.throughput >= gr.throughput * 0.95,
            "SA {} vs greedy {}",
            sa.throughput,
            gr.throughput
        );
        assert!(
            sa.throughput >= rs.throughput,
            "SA {} vs random {}",
            sa.throughput,
            rs.throughput
        );
    }

    #[test]
    fn greedy_is_deterministic() {
        let p = problem(0.5);
        let a = greedy(&p);
        let b = greedy(&p);
        assert_eq!(a.mapping.foldings, b.mapping.foldings);
        assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
        assert_eq!(a.ii, b.ii);
        assert_eq!(a.resources, b.resources);
    }

    #[test]
    fn random_search_is_seed_deterministic_and_feasible() {
        let p = problem(0.4);
        let cfg = AnnealConfig::quick();
        let a = random_search(&p, &cfg);
        let b = random_search(&p, &cfg);
        assert_eq!(a.mapping.foldings, b.mapping.foldings, "same seed, same search");
        assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
        assert!(a.feasible);
        assert!(a.resources.fits_in(&p.budget));
        // The evaluation budget is the annealer's (iterations × restarts).
        assert_eq!(a.iterations_run, cfg.iterations * cfg.restarts);
        // A budget too small even for the minimal mapping falls back to
        // the minimal mapping and reports it infeasible, not a panic.
        let mut starved = problem(0.4);
        starved.budget = ResourceVec::new(10, 10, 1, 1);
        let f = random_search(&starved, &cfg);
        assert!(!f.feasible);
        assert_eq!(f.mapping.foldings, starved.mapping.foldings);
    }

    #[test]
    fn heuristic_baselines_never_beat_the_exact_oracle() {
        // The ablation ordering behind the paper's comparison tables:
        // every heuristic is bounded by the certified optimum
        // (DESIGN.md §13) on a problem small enough to solve exactly.
        use crate::dse::exact::{exact, ExactConfig, ExactOutcome};
        let mut p = problem(0.5);
        p.active.truncate(3);
        let ExactOutcome::Optimal(opt) = exact(&p, &ExactConfig::default()) else {
            panic!("truncated baseline problem must be exactly solvable");
        };
        let gr = greedy(&p);
        let rs = random_search(&p, &AnnealConfig::quick());
        for (name, r) in [("greedy", &gr), ("random", &rs)] {
            if r.feasible {
                assert!(
                    r.ii >= opt.ii,
                    "{name} beat the exact oracle: {} < {}",
                    r.ii,
                    opt.ii
                );
            }
        }
    }

    #[test]
    fn naive_combine_fits_budget_or_reports_none() {
        let pt = |thr: f64, dsp: u64| TapPoint {
            resources: ResourceVec::new(dsp * 10, dsp * 10, dsp, 10),
            throughput: thr,
            ii: 1,
            budget_fraction: 0.0,
            source: 0,
        };
        let f = TapCurve::from_points(vec![pt(100.0, 100), pt(390.0, 650)]);
        let g = TapCurve::from_points(vec![pt(90.0, 90), pt(400.0, 650)]);
        // A budget that fits the cheap pair: the pick fits, ignores p
        // (the strawman's defining shape), and rates at the stage min.
        let budget = ResourceVec::new(4_000, 4_000, 250, 1_000);
        let d = naive_combine(&f, &g, &budget).unwrap();
        assert!((d.stage1.resources + d.stage2.resources).fits_in(&budget));
        assert_eq!(d.p, 1.0, "naive allocation is blind to p");
        assert_eq!(
            d.throughput_at_p,
            d.stage1.throughput.min(d.stage2.throughput)
        );
        // Nothing fits: no silent wrong answer.
        assert!(naive_combine(&f, &g, &ResourceVec::new(10, 10, 1, 1)).is_none());
    }

    #[test]
    fn naive_combine_ignores_p_and_loses() {
        // Construct curves where probability-aware allocation wins: the
        // second stage can be 4x under-provisioned at p=0.25.
        let pt = |thr: f64, dsp: u64| TapPoint {
            resources: ResourceVec::new(dsp * 10, dsp * 10, dsp, 10),
            throughput: thr,
            ii: 1,
            budget_fraction: 0.0,
            source: 0,
        };
        // Stage 1 has an expensive fast point that only pairs with the
        // small stage-2 point; the naive lockstep walk (blind to 1/p)
        // steps stage 1 down instead of exploiting that pairing.
        let f = TapCurve::from_points(vec![pt(100.0, 100), pt(390.0, 650)]);
        let g = TapCurve::from_points(vec![pt(90.0, 90), pt(400.0, 650)]);
        let budget = ResourceVec::new(10_000, 10_000, 740, 1_000);
        let naive = naive_combine(&f, &g, &budget).unwrap();
        let eq1 = combine(&f, &g, 0.25, &budget).unwrap();
        assert!(
            eq1.throughput_at(0.25) > naive.throughput_at(0.25),
            "Eq.1 {} should beat naive {}",
            eq1.throughput_at(0.25),
            naive.throughput_at(0.25)
        );
    }
}
