//! Simulated-annealing search over folding assignments (§II-C: "The tool
//! performs Design Space Exploration to optimize the hardware architecture
//! using simulated annealing to select possible incremental transformations
//! to the hardware blocks").
//!
//! State      : one folding per active node.
//! Move       : step one folding axis of one node up/down its divisor
//!              ladder (the "incremental transformation").
//! Energy     : ln(II) + resource-overrun penalty. Log-space keeps the
//!              acceptance rule scale-free across networks whose IIs span
//!              decades.
//! Schedule   : geometric cooling, multiple restarts, best-feasible kept.

use std::sync::atomic::{AtomicU64, Ordering};

use super::problem::Problem;
use crate::sdf::folding::FoldingSpace;
use crate::sdf::HwMapping;
use crate::util::Rng;

/// Process-wide count of [`anneal`] invocations. The pipeline's artifact
/// cache is contractually "zero anneal calls on a warm store"; this
/// counter lets tests (and operators, via `atheena toolflow`'s summary)
/// verify that contract instead of trusting it.
static ANNEAL_CALLS: AtomicU64 = AtomicU64::new(0);

/// Total `anneal` calls made by this process so far.
pub fn anneal_call_count() -> u64 {
    ANNEAL_CALLS.load(Ordering::Relaxed)
}

#[derive(Clone, Debug)]
pub struct AnnealConfig {
    pub iterations: usize,
    pub restarts: usize,
    /// Initial temperature (in energy units; energy is ln-II based).
    pub t0: f64,
    /// Geometric cooling factor applied every iteration.
    pub alpha: f64,
    pub seed: u64,
}

impl Default for AnnealConfig {
    fn default() -> Self {
        AnnealConfig {
            iterations: 4_000,
            restarts: 4,
            t0: 1.0,
            alpha: 0.9985,
            seed: 0xA7_EE_17,
        }
    }
}

impl AnnealConfig {
    /// Faster schedule for tests and smoke runs.
    pub fn quick() -> AnnealConfig {
        AnnealConfig {
            iterations: 800,
            restarts: 2,
            ..Default::default()
        }
    }
}

/// Outcome of one DSE run.
#[derive(Clone, Debug)]
pub struct AnnealResult {
    pub mapping: HwMapping,
    pub ii: u64,
    pub throughput: f64,
    pub resources: crate::resources::ResourceVec,
    /// Whether any feasible point was found at all (tight budgets can be
    /// infeasible even fully folded).
    pub feasible: bool,
    pub iterations_run: usize,
}

/// Incremental evaluation cache: per-node II and resources plus the
/// running totals, so a single-node proposal costs one resource-model
/// call and an O(active) u64 max-scan instead of re-evaluating the whole
/// design (§Perf: this took the annealer from ~2.2M to >4M proposals/s).
struct EvalCache {
    ii: Vec<u64>,
    res: Vec<crate::resources::ResourceVec>,
    total_res: crate::resources::ResourceVec,
}

impl EvalCache {
    fn new(problem: &Problem, mapping: &HwMapping) -> EvalCache {
        let ii: Vec<u64> = (0..mapping.cdfg.nodes.len())
            .map(|id| mapping.node_ii(id))
            .collect();
        let res: Vec<_> = (0..mapping.cdfg.nodes.len())
            .map(|id| mapping.node_resources(id))
            .collect();
        let mut total_res = if Problem::charges_infrastructure(problem.kind) {
            crate::resources::model::infrastructure()
        } else {
            crate::resources::ResourceVec::ZERO
        };
        for &id in &problem.active {
            total_res += res[id];
        }
        EvalCache { ii, res, total_res }
    }

    /// Apply a single-node folding change; returns the previous (ii, res)
    /// for undo.
    fn update(
        &mut self,
        mapping: &HwMapping,
        id: usize,
    ) -> (u64, crate::resources::ResourceVec) {
        let old = (self.ii[id], self.res[id]);
        let new_ii = mapping.node_ii(id);
        let new_res = mapping.node_resources(id);
        self.total_res = self.total_res.saturating_sub(&old.1) + new_res;
        self.ii[id] = new_ii;
        self.res[id] = new_res;
        old
    }

    fn undo(&mut self, id: usize, old: (u64, crate::resources::ResourceVec)) {
        self.total_res = self.total_res.saturating_sub(&self.res[id]) + old.1;
        self.ii[id] = old.0;
        self.res[id] = old.1;
    }

    fn max_ii(&self, active: &[usize]) -> u64 {
        active.iter().map(|&id| self.ii[id]).max().unwrap_or(1)
    }
}

/// Energy: ln(II), plus a steep penalty proportional to how far the
/// design exceeds the budget (lets the search traverse slightly
/// infeasible regions without settling there).
fn energy_cached(problem: &Problem, cache: &EvalCache) -> f64 {
    let ii = cache.max_ii(&problem.active) as f64;
    let over = cache.total_res.max_utilisation(&problem.budget);
    let penalty = if over > 1.0 { 8.0 * (over - 1.0) } else { 0.0 };
    ii.ln() + penalty
}

/// Propose a neighbouring state: mutate one axis of one active node.
/// Returns the node id and its previous folding for undo.
fn propose(
    problem: &Problem,
    mapping: &mut HwMapping,
    rng: &mut Rng,
) -> Option<(usize, crate::sdf::Folding)> {
    // Try a handful of times to find a mutable axis (EE layers are fixed).
    for _ in 0..16 {
        let id = *rng.choose(&problem.active);
        let space = &mapping.spaces[id];
        let cur = mapping.foldings[id];
        let axis = rng.below(3);
        let up = rng.chance(0.5);
        let next = match axis {
            0 => FoldingSpace::step(&space.coarse_in, cur.coarse_in, up)
                .map(|v| crate::sdf::Folding { coarse_in: v, ..cur }),
            1 => FoldingSpace::step(&space.coarse_out, cur.coarse_out, up)
                .map(|v| crate::sdf::Folding { coarse_out: v, ..cur }),
            _ => FoldingSpace::step(&space.fine, cur.fine, up)
                .map(|v| crate::sdf::Folding { fine: v, ..cur }),
        };
        if let Some(next) = next {
            mapping.foldings[id] = next;
            return Some((id, cur));
        }
    }
    None
}

/// Run simulated annealing for one problem; returns the best feasible
/// design found across all restarts (or the least-infeasible one).
pub fn anneal(problem: &Problem, cfg: &AnnealConfig) -> AnnealResult {
    ANNEAL_CALLS.fetch_add(1, Ordering::Relaxed);
    let mut best: Option<(f64, HwMapping)> = None; // (throughput, mapping)
    let mut best_infeasible: Option<(f64, HwMapping)> = None; // (overrun, ..)
    let mut iterations_run = 0;

    for restart in 0..cfg.restarts {
        let mut rng = Rng::new(cfg.seed ^ (restart as u64).wrapping_mul(0x9E37));
        let mut mapping = problem.mapping.clone();
        // Random warm start: a few random uphill steps diversify restarts.
        for _ in 0..problem.active.len() * 2 {
            let _ = propose(problem, &mut mapping, &mut rng);
        }
        let mut cache = EvalCache::new(problem, &mapping);
        let mut e = energy_cached(problem, &cache);
        let mut t = cfg.t0;

        for _ in 0..cfg.iterations {
            iterations_run += 1;
            t *= cfg.alpha;
            let Some((id, prev)) = propose(problem, &mut mapping, &mut rng) else {
                continue;
            };
            let old_entry = cache.update(&mapping, id);
            let e_new = energy_cached(problem, &cache);
            let accept = e_new <= e || rng.f64() < ((e - e_new) / t.max(1e-9)).exp();
            if accept {
                e = e_new;
                // Track the best *feasible* design seen anywhere.
                if cache.total_res.fits_in(&problem.budget) {
                    let thr = problem.clock_hz / cache.max_ii(&problem.active) as f64;
                    if best.as_ref().map(|(b, _)| thr > *b).unwrap_or(true) {
                        best = Some((thr, mapping.clone()));
                    }
                } else {
                    let over = cache.total_res.max_utilisation(&problem.budget);
                    if best_infeasible
                        .as_ref()
                        .map(|(b, _)| over < *b)
                        .unwrap_or(true)
                    {
                        best_infeasible = Some((over, mapping.clone()));
                    }
                }
            } else {
                mapping.foldings[id] = prev; // undo
                cache.undo(id, old_entry);
            }
        }
    }

    let (mapping, feasible) = match best {
        Some((_, m)) => (m, true),
        None => (
            best_infeasible
                .map(|(_, m)| m)
                .unwrap_or_else(|| problem.mapping.clone()),
            false,
        ),
    };
    let ii = problem.ii(&mapping);
    AnnealResult {
        throughput: problem.clock_hz / ii as f64,
        resources: problem.resources(&mapping),
        ii,
        mapping,
        feasible,
        iterations_run,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Problem;
    use crate::ir::network::testnet;
    use crate::ir::Cdfg;
    use crate::resources::Board;

    #[test]
    fn annealer_improves_over_minimal() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.resources,
            board.clock_hz,
        );
        let start_thr = p.throughput(&p.mapping);
        let r = anneal(&p, &AnnealConfig::quick());
        assert!(r.feasible);
        assert!(
            r.throughput > start_thr * 5.0,
            "annealer should vastly outperform the fully-folded start \
             ({start_thr} -> {})",
            r.throughput
        );
        assert!(r.resources.fits_in(&board.resources));
    }

    #[test]
    fn annealer_respects_budget() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let budget = board.budget(0.25);
        let p = Problem::baseline(Cdfg::lower_baseline(&net), budget, board.clock_hz);
        let r = anneal(&p, &AnnealConfig::quick());
        assert!(r.feasible);
        assert!(r.resources.fits_in(&budget));
    }

    #[test]
    fn deterministic_given_seed() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.resources,
            board.clock_hz,
        );
        let cfg = AnnealConfig::quick();
        let a = anneal(&p, &cfg);
        let b = anneal(&p, &cfg);
        assert_eq!(a.ii, b.ii);
        assert_eq!(a.resources, b.resources);
    }

    #[test]
    fn bigger_budget_never_worse() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cfg = AnnealConfig::quick();
        let small = anneal(
            &Problem::baseline(Cdfg::lower_baseline(&net), board.budget(0.2), board.clock_hz),
            &cfg,
        );
        let large = anneal(
            &Problem::baseline(Cdfg::lower_baseline(&net), board.budget(1.0), board.clock_hz),
            &cfg,
        );
        // SA is stochastic but with the same schedule the larger budget
        // must not lose by more than noise; enforce the strong form since
        // seeds are fixed.
        assert!(large.throughput >= small.throughput * 0.95);
    }
}
