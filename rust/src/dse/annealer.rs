//! Simulated-annealing search over folding assignments (§II-C: "The tool
//! performs Design Space Exploration to optimize the hardware architecture
//! using simulated annealing to select possible incremental transformations
//! to the hardware blocks").
//!
//! State      : one folding per active node.
//! Move       : step one folding axis of one node up/down its divisor
//!              ladder (the "incremental transformation").
//! Energy     : objective-aware, computed in O(1) from the incremental
//!              [`EvalCache`]:
//!              * `MaxThroughput` / `ParetoFront` — ln(II) +
//!                resource-overrun penalty (log-space keeps the
//!                acceptance rule scale-free across networks whose IIs
//!                span decades); the two objectives share one arm so a
//!                frontier-mode anneal is bit-identical to a
//!                max-throughput one,
//!              * `MinAreaAtThroughput(target)` — the scalar area norm
//!                (limiting-resource utilisation of the budget) + the
//!                same overrun penalty + a log-space throughput
//!                shortfall penalty while the design misses the target.
//! Schedule   : geometric cooling, multiple restarts (independent RNG
//!              streams, run in parallel on the deterministic executor
//!              and reduced bit-identically to the sequential loop),
//!              best design under the objective kept (highest
//!              throughput, or lowest area among target-meeting
//!              designs).

use std::sync::atomic::{AtomicU64, Ordering};

use super::problem::{Objective, Problem};
use crate::sdf::folding::FoldingSpace;
use crate::sdf::HwMapping;
use crate::util::Rng;

/// Process-wide count of [`anneal`] invocations. The pipeline's artifact
/// cache is contractually "zero anneal calls on a warm store"; this
/// counter lets tests (and operators, via `atheena toolflow`'s summary)
/// verify that contract instead of trusting it.
static ANNEAL_CALLS: AtomicU64 = AtomicU64::new(0);

/// Total `anneal` calls made by this process so far.
pub fn anneal_call_count() -> u64 {
    ANNEAL_CALLS.load(Ordering::Relaxed)
}

#[derive(Clone, Debug)]
pub struct AnnealConfig {
    pub iterations: usize,
    pub restarts: usize,
    /// Initial temperature (in energy units; energy is ln-II based).
    pub t0: f64,
    /// Geometric cooling factor applied every iteration.
    pub alpha: f64,
    pub seed: u64,
}

impl Default for AnnealConfig {
    fn default() -> Self {
        AnnealConfig {
            iterations: 4_000,
            restarts: 4,
            t0: 1.0,
            alpha: 0.9985,
            seed: 0xA7_EE_17,
        }
    }
}

impl AnnealConfig {
    /// Faster schedule for tests and smoke runs.
    pub fn quick() -> AnnealConfig {
        AnnealConfig {
            iterations: 800,
            restarts: 2,
            ..Default::default()
        }
    }
}

/// Outcome of one DSE run.
#[derive(Clone, Debug)]
pub struct AnnealResult {
    pub mapping: HwMapping,
    pub ii: u64,
    pub throughput: f64,
    pub resources: crate::resources::ResourceVec,
    /// Whether any qualifying point was found at all: budget-feasible,
    /// and for [`Objective::MinAreaAtThroughput`] also meeting the
    /// throughput target (tight budgets can be infeasible even fully
    /// folded; tight targets can be unreachable even at full budget).
    pub feasible: bool,
    pub iterations_run: usize,
    /// Proposals accepted across all restarts (Metropolis acceptances,
    /// including downhill moves). `accepted / iterations_run` is the
    /// acceptance rate the perf benches record alongside the warm-start
    /// speedup — a chain whose warm seeds are good accepts fewer uphill
    /// repairs.
    pub accepted: usize,
}

/// Incremental evaluation cache: per-node II and resources plus the
/// running totals, so a single-node proposal costs one resource-model
/// call and O(1) bookkeeping instead of re-evaluating the whole design
/// (§Perf: the per-node cache took the annealer from ~2.2M to >4M
/// proposals/s; replacing the per-proposal O(active) max-II scan with
/// count-of-max tracking removes the last per-proposal scan — both the
/// energy and the accept-branch throughput read the cached maximum).
///
/// Invariants (`debug_assert`ed in `rescan_max`):
/// * `max_ii == max(ii[id] for id in active)` (1 when `active` is
///   empty),
/// * `n_at_max == |{id in active : ii[id] == max_ii}|`.
///
/// Updates repair the pair in O(1) except when the *unique* maximum
/// decreases, which triggers a lazy O(active) rescan — the classic
/// count-of-max scheme. Rejected proposals undo through the same
/// bookkeeping, so no energy recomputation happens on the undo path.
///
/// Crate-visible: `dse::exact` reuses the same cache as its leaf
/// evaluator (assign-candidate / undo around each branch-and-bound
/// descent), so the exact oracle and the annealer score leaves through
/// identical bookkeeping.
pub(crate) struct EvalCache {
    ii: Vec<u64>,
    res: Vec<crate::resources::ResourceVec>,
    pub(crate) total_res: crate::resources::ResourceVec,
    /// Active node ids (the nodes `max_ii` ranges over).
    active_ids: Vec<usize>,
    /// Membership mask over all node ids.
    is_active: Vec<bool>,
    max_ii: u64,
    n_at_max: usize,
}

impl EvalCache {
    pub(crate) fn new(problem: &Problem, mapping: &HwMapping) -> EvalCache {
        let ii: Vec<u64> = (0..mapping.cdfg.nodes.len())
            .map(|id| mapping.node_ii(id))
            .collect();
        let res: Vec<_> = (0..mapping.cdfg.nodes.len())
            .map(|id| mapping.node_resources(id))
            .collect();
        let mut total_res = if Problem::charges_infrastructure(problem.kind) {
            crate::resources::model::infrastructure()
        } else {
            crate::resources::ResourceVec::ZERO
        };
        for &id in &problem.active {
            total_res += res[id];
        }
        let mut is_active = vec![false; mapping.cdfg.nodes.len()];
        for &id in &problem.active {
            is_active[id] = true;
        }
        let mut cache = EvalCache {
            ii,
            res,
            total_res,
            active_ids: problem.active.clone(),
            is_active,
            max_ii: 1,
            n_at_max: 0,
        };
        cache.rescan_max();
        cache
    }

    fn rescan_max(&mut self) {
        self.max_ii = self
            .active_ids
            .iter()
            .map(|&id| self.ii[id])
            .max()
            .unwrap_or(1);
        self.n_at_max = self
            .active_ids
            .iter()
            .filter(|&&id| self.ii[id] == self.max_ii)
            .count();
    }

    /// Repair (`max_ii`, `n_at_max`) after one active node's II moved
    /// from `old_ii` to `new_ii` (already written into `self.ii`).
    fn track(&mut self, old_ii: u64, new_ii: u64) {
        if new_ii == old_ii {
            return;
        }
        if new_ii > self.max_ii {
            // A new, strictly larger maximum: this node is its only
            // holder (everything else was ≤ the old max).
            self.max_ii = new_ii;
            self.n_at_max = 1;
            return;
        }
        if new_ii == self.max_ii {
            self.n_at_max += 1;
        }
        if old_ii == self.max_ii {
            self.n_at_max -= 1;
            if self.n_at_max == 0 {
                // The unique maximum decreased: lazy argmax repair.
                self.rescan_max();
            }
        }
    }

    /// Apply a single-node folding change; returns the previous (ii, res)
    /// for undo.
    pub(crate) fn update(
        &mut self,
        mapping: &HwMapping,
        id: usize,
    ) -> (u64, crate::resources::ResourceVec) {
        let old = (self.ii[id], self.res[id]);
        let new_ii = mapping.node_ii(id);
        let new_res = mapping.node_resources(id);
        self.total_res = self.total_res.saturating_sub(&old.1) + new_res;
        self.ii[id] = new_ii;
        self.res[id] = new_res;
        if self.is_active[id] {
            self.track(old.0, new_ii);
        }
        old
    }

    pub(crate) fn undo(&mut self, id: usize, old: (u64, crate::resources::ResourceVec)) {
        self.total_res = self.total_res.saturating_sub(&self.res[id]) + old.1;
        let prev_ii = self.ii[id];
        self.ii[id] = old.0;
        self.res[id] = old.1;
        if self.is_active[id] {
            self.track(prev_ii, old.0);
        }
    }

    /// Maximum II over the active nodes — O(1), maintained
    /// incrementally.
    pub(crate) fn max_active_ii(&self) -> u64 {
        self.max_ii
    }
}

/// Objective-aware energy, O(1) from the cache. All objectives share
/// the steep budget-overrun penalty (lets the search traverse slightly
/// infeasible regions without settling there); `MaxThroughput` and
/// `ParetoFront` deliberately share one arm — identical float ops —
/// so frontier-mode anneals are bit-identical to max-throughput ones.
fn energy_cached(problem: &Problem, cache: &EvalCache) -> f64 {
    let over = cache.total_res.max_utilisation(&problem.budget);
    let penalty = if over > 1.0 { 8.0 * (over - 1.0) } else { 0.0 };
    match problem.objective {
        Objective::MinAreaAtThroughput(target) => {
            // Minimize area (the utilisation norm doubles as the energy
            // term below budget), with a log-space shortfall penalty
            // while throughput misses the target.
            let thr = problem.clock_hz / cache.max_active_ii() as f64;
            let shortfall = if thr < target {
                4.0 * (target / thr).ln()
            } else {
                0.0
            };
            over + penalty + shortfall
        }
        Objective::MaxThroughput | Objective::ParetoFront => {
            let ii = cache.max_active_ii() as f64;
            ii.ln() + penalty
        }
    }
}

/// Higher-is-better score of a *budget-feasible* state under the
/// problem's objective, or `None` when the state does not qualify as a
/// solution (a `MinAreaAtThroughput` design below its target).
/// `MaxThroughput`/`ParetoFront` score by throughput — exactly the
/// pre-objective tracking, bit for bit.
fn objective_score(problem: &Problem, cache: &EvalCache) -> Option<f64> {
    match problem.objective {
        Objective::MinAreaAtThroughput(target) => {
            let thr = problem.clock_hz / cache.max_active_ii() as f64;
            (thr >= target).then(|| -cache.total_res.max_utilisation(&problem.budget))
        }
        Objective::MaxThroughput | Objective::ParetoFront => {
            Some(problem.clock_hz / cache.max_active_ii() as f64)
        }
    }
}

/// Distance-from-feasible metric for states that are not a qualifying
/// solution: budget overrun, and for `MinAreaAtThroughput` also the
/// factor by which throughput misses the target — lower is closer.
fn infeasibility(problem: &Problem, cache: &EvalCache) -> f64 {
    let over = cache.total_res.max_utilisation(&problem.budget);
    match problem.objective {
        Objective::MinAreaAtThroughput(target) => {
            let thr = problem.clock_hz / cache.max_active_ii() as f64;
            over.max(target / thr)
        }
        Objective::MaxThroughput | Objective::ParetoFront => over,
    }
}

/// Propose a neighbouring state: mutate one axis of one active node.
/// Returns the node id and its previous folding for undo.
fn propose(
    problem: &Problem,
    mapping: &mut HwMapping,
    rng: &mut Rng,
) -> Option<(usize, crate::sdf::Folding)> {
    // Try a handful of times to find a mutable axis (EE layers are fixed).
    for _ in 0..16 {
        let id = *rng.choose(&problem.active);
        let space = &mapping.spaces[id];
        let cur = mapping.foldings[id];
        let axis = rng.below(3);
        let up = rng.chance(0.5);
        let next = match axis {
            0 => FoldingSpace::step(&space.coarse_in, cur.coarse_in, up)
                .map(|v| crate::sdf::Folding { coarse_in: v, ..cur }),
            1 => FoldingSpace::step(&space.coarse_out, cur.coarse_out, up)
                .map(|v| crate::sdf::Folding { coarse_out: v, ..cur }),
            _ => FoldingSpace::step(&space.fine, cur.fine, up)
                .map(|v| crate::sdf::Folding { fine: v, ..cur }),
        };
        if let Some(next) = next {
            mapping.foldings[id] = next;
            return Some((id, cur));
        }
    }
    None
}

/// What one restart's independent search found.
struct RestartOutcome {
    /// Best qualifying design: (objective score, mapping). The score is
    /// throughput for `MaxThroughput`/`ParetoFront`, negated area norm
    /// for `MinAreaAtThroughput` — higher always better.
    best: Option<(f64, HwMapping)>,
    /// Closest non-qualifying design: (infeasibility, mapping).
    best_infeasible: Option<(f64, HwMapping)>,
    iterations: usize,
    accepted: usize,
}

/// One restart's full annealing schedule. Each restart derives its own
/// RNG from (seed, restart index), so restarts are independent pure
/// functions — the executor runs them in parallel and the reduction in
/// [`reduce_restarts`] reproduces the sequential loop bit for bit.
fn run_restart(problem: &Problem, cfg: &AnnealConfig, restart: usize) -> RestartOutcome {
    run_restart_seeded(problem, cfg, restart, None)
}

/// [`run_restart`] with an optional **warm seed**: when `warm` is
/// `Some`, the trajectory starts from that mapping verbatim (no random
/// diversification steps) and the seed state itself is recorded as the
/// initial best before the first proposal — so a warm-started restart
/// can never return a design worse (under the objective score) than the
/// seed it was given. When `warm` is `None` this is byte-for-byte the
/// original cold restart: same RNG draws, same warm-up proposals, same
/// trajectory.
fn run_restart_seeded(
    problem: &Problem,
    cfg: &AnnealConfig,
    restart: usize,
    warm: Option<&HwMapping>,
) -> RestartOutcome {
    let mut rng = Rng::new(cfg.seed ^ (restart as u64).wrapping_mul(0x9E37));
    let mut mapping = match warm {
        Some(seed) => seed.clone(),
        None => problem.mapping.clone(),
    };
    if warm.is_none() {
        // Random warm start: a few random uphill steps diversify restarts.
        for _ in 0..problem.active.len() * 2 {
            let _ = propose(problem, &mut mapping, &mut rng);
        }
    }
    let mut cache = EvalCache::new(problem, &mapping);
    let mut e = energy_cached(problem, &cache);
    let mut t = cfg.t0;

    let mut best: Option<(f64, HwMapping)> = None;
    let mut best_infeasible: Option<(f64, HwMapping)> = None;
    if warm.is_some() {
        // The clipped seed is a real candidate, not just a start state:
        // recording it up front is the exact floor the warm-start
        // dominance property stands on.
        match if cache.total_res.fits_in(&problem.budget) {
            objective_score(problem, &cache)
        } else {
            None
        } {
            Some(score) => best = Some((score, mapping.clone())),
            None => best_infeasible = Some((infeasibility(problem, &cache), mapping.clone())),
        }
    }
    let mut iterations = 0;
    let mut accepted = 0;
    for _ in 0..cfg.iterations {
        iterations += 1;
        t *= cfg.alpha;
        let Some((id, prev)) = propose(problem, &mut mapping, &mut rng) else {
            continue;
        };
        let old_entry = cache.update(&mapping, id);
        let e_new = energy_cached(problem, &cache);
        let accept = e_new <= e || rng.f64() < ((e - e_new) / t.max(1e-9)).exp();
        if accept {
            accepted += 1;
            e = e_new;
            // Track the best *qualifying* design seen in this restart
            // (budget-feasible, and — for MinAreaAtThroughput — meeting
            // the throughput target).
            let qualifying = if cache.total_res.fits_in(&problem.budget) {
                objective_score(problem, &cache)
            } else {
                None
            };
            match qualifying {
                Some(score) => {
                    if best.as_ref().map(|(b, _)| score > *b).unwrap_or(true) {
                        best = Some((score, mapping.clone()));
                    }
                }
                None => {
                    let dist = infeasibility(problem, &cache);
                    if best_infeasible
                        .as_ref()
                        .map(|(b, _)| dist < *b)
                        .unwrap_or(true)
                    {
                        best_infeasible = Some((dist, mapping.clone()));
                    }
                }
            }
        } else {
            // Undo: the cached energy state is restored incrementally —
            // no energy recomputation on the rejected path.
            mapping.foldings[id] = prev;
            cache.undo(id, old_entry);
        }
    }
    RestartOutcome {
        best,
        best_infeasible,
        iterations,
        accepted,
    }
}

/// Fold per-restart outcomes (in restart order) into the final result.
///
/// Strict comparisons make the tie-break deterministic on
/// (objective score, restart index): the sequential loop's global best
/// is the first (restart, iteration) to attain the maximum score, and
/// reducing per-restart bests in restart order with `>` picks exactly
/// that restart — so the parallel path is bit-identical to the
/// sequential one (property-tested in `tests/pipeline_props.rs`).
fn reduce_restarts(problem: &Problem, outcomes: Vec<RestartOutcome>) -> AnnealResult {
    let mut best: Option<(f64, HwMapping)> = None;
    let mut best_infeasible: Option<(f64, HwMapping)> = None;
    let mut iterations_run = 0;
    let mut accepted = 0;
    for o in outcomes {
        iterations_run += o.iterations;
        accepted += o.accepted;
        if let Some((score, m)) = o.best {
            if best.as_ref().map(|(b, _)| score > *b).unwrap_or(true) {
                best = Some((score, m));
            }
        }
        if let Some((over, m)) = o.best_infeasible {
            if best_infeasible
                .as_ref()
                .map(|(b, _)| over < *b)
                .unwrap_or(true)
            {
                best_infeasible = Some((over, m));
            }
        }
    }

    let (mapping, feasible) = match best {
        Some((_, m)) => (m, true),
        None => (
            best_infeasible
                .map(|(_, m)| m)
                .unwrap_or_else(|| problem.mapping.clone()),
            false,
        ),
    };
    let ii = problem.ii(&mapping);
    AnnealResult {
        throughput: problem.clock_hz / ii as f64,
        resources: problem.resources(&mapping),
        ii,
        mapping,
        feasible,
        iterations_run,
        accepted,
    }
}

/// Run simulated annealing for one problem; returns the best feasible
/// design found across all restarts (or the least-infeasible one).
///
/// Restarts run on the deterministic executor (sequentially when the
/// caller is already an executor worker — e.g. inside a parallel TAP
/// sweep — so the thread count stays bounded). The result is
/// bit-identical to [`anneal_sequential`].
pub fn anneal(problem: &Problem, cfg: &AnnealConfig) -> AnnealResult {
    ANNEAL_CALLS.fetch_add(1, Ordering::Relaxed);
    let outcomes = crate::util::exec::run_ordered(cfg.restarts, |restart| {
        run_restart(problem, cfg, restart)
    });
    reduce_restarts(problem, outcomes)
}

/// Sequential reference path for [`anneal`] — the pre-parallel
/// restart-by-restart loop, kept for the bit-identicality property
/// tests and single-threaded debugging.
pub fn anneal_sequential(problem: &Problem, cfg: &AnnealConfig) -> AnnealResult {
    ANNEAL_CALLS.fetch_add(1, Ordering::Relaxed);
    let outcomes = (0..cfg.restarts)
        .map(|restart| run_restart(problem, cfg, restart))
        .collect();
    reduce_restarts(problem, outcomes)
}

/// Warm-started anneal: restart 0 runs the full schedule from
/// `seed_mapping` (recorded as the initial best, so the result's
/// objective score can never fall below the seed's), and restarts ≥ 1 —
/// if the config asks for any — replay the *cold* restart streams of
/// the same config exactly (`run_restart(problem, cfg, r)`), keeping a
/// diversification escape hatch whose trajectories are bit-identical to
/// the corresponding cold-anneal restarts.
///
/// This is the warm-start contract `dse::pareto`'s budget-ladder
/// chaining relies on (DESIGN.md §11): a deterministic *seed* change,
/// never a silent result change — the search itself is the same
/// annealer, the reduction the same [`reduce_restarts`].
pub fn anneal_seeded(
    problem: &Problem,
    cfg: &AnnealConfig,
    seed_mapping: &HwMapping,
) -> AnnealResult {
    ANNEAL_CALLS.fetch_add(1, Ordering::Relaxed);
    let outcomes = crate::util::exec::run_ordered(cfg.restarts.max(1), |restart| {
        if restart == 0 {
            run_restart_seeded(problem, cfg, 0, Some(seed_mapping))
        } else {
            run_restart(problem, cfg, restart)
        }
    });
    reduce_restarts(problem, outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Problem;
    use crate::ir::network::testnet;
    use crate::ir::Cdfg;
    use crate::resources::Board;

    #[test]
    fn annealer_improves_over_minimal() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.resources,
            board.clock_hz,
        );
        let start_thr = p.throughput(&p.mapping);
        let r = anneal(&p, &AnnealConfig::quick());
        assert!(r.feasible);
        assert!(
            r.throughput > start_thr * 5.0,
            "annealer should vastly outperform the fully-folded start \
             ({start_thr} -> {})",
            r.throughput
        );
        assert!(r.resources.fits_in(&board.resources));
    }

    #[test]
    fn annealer_respects_budget() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let budget = board.budget(0.25);
        let p = Problem::baseline(Cdfg::lower_baseline(&net), budget, board.clock_hz);
        let r = anneal(&p, &AnnealConfig::quick());
        assert!(r.feasible);
        assert!(r.resources.fits_in(&budget));
    }

    #[test]
    fn deterministic_given_seed() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.resources,
            board.clock_hz,
        );
        let cfg = AnnealConfig::quick();
        let a = anneal(&p, &cfg);
        let b = anneal(&p, &cfg);
        assert_eq!(a.ii, b.ii);
        assert_eq!(a.resources, b.resources);
    }

    #[test]
    fn parallel_restarts_bit_identical_to_sequential() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        for (kind_budget, cdfg) in [
            (board.resources, Cdfg::lower_baseline(&net)),
            (board.budget(0.3), Cdfg::lower_baseline(&net)),
        ] {
            let p = Problem::baseline(cdfg, kind_budget, board.clock_hz);
            let cfg = AnnealConfig {
                iterations: 500,
                restarts: 3,
                ..Default::default()
            };
            let par = anneal(&p, &cfg);
            let seq = anneal_sequential(&p, &cfg);
            assert_eq!(par.ii, seq.ii);
            assert_eq!(par.resources, seq.resources);
            assert_eq!(par.feasible, seq.feasible);
            assert_eq!(par.iterations_run, seq.iterations_run);
            assert_eq!(par.throughput.to_bits(), seq.throughput.to_bits());
            assert_eq!(par.mapping.foldings, seq.mapping.foldings);
        }
    }

    #[test]
    fn pareto_front_objective_bit_identical_to_max_throughput() {
        // ParetoFront is a sweep of per-budget MaxThroughput searches;
        // a single anneal under either objective must be the same
        // search, bit for bit.
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cfg = AnnealConfig::quick();
        let base = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.budget(0.5),
            board.clock_hz,
        );
        let a = anneal(&base.clone().with_objective(Objective::MaxThroughput), &cfg);
        let b = anneal(&base.with_objective(Objective::ParetoFront), &cfg);
        assert_eq!(a.ii, b.ii);
        assert_eq!(a.resources, b.resources);
        assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
        assert_eq!(a.mapping.foldings, b.mapping.foldings);
    }

    #[test]
    fn min_area_objective_meets_target_with_less_area() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cfg = AnnealConfig::quick();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.resources,
            board.clock_hz,
        );
        let fast = anneal(&p, &cfg);
        assert!(fast.feasible);
        // Ask for half the max throughput at minimum area: the result
        // must meet the target and shed area vs the max-throughput
        // design.
        let target = fast.throughput * 0.5;
        let cheap = anneal(
            &p.clone().with_objective(Objective::MinAreaAtThroughput(target)),
            &cfg,
        );
        assert!(cheap.feasible, "half the max throughput must be reachable");
        assert!(cheap.throughput >= target);
        assert!(cheap.resources.fits_in(&board.resources));
        // Two independent SA trajectories carry no cross-run guarantee,
        // so only the objective's own contract is asserted here; the
        // strong "never beaten by a cheaper qualifying design" property
        // is enforced against the frontier in `dse::pareto` and
        // `tests/pareto_props.rs`.
        assert!(cheap.resources.utilization(&board.resources) <= 1.0);
    }

    #[test]
    fn min_area_unreachable_target_reports_infeasible() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.resources,
            board.clock_hz,
        )
        .with_objective(Objective::MinAreaAtThroughput(f64::INFINITY));
        let r = anneal(&p, &AnnealConfig::quick());
        assert!(!r.feasible, "an infinite target can never qualify");
    }

    #[test]
    fn seeded_anneal_never_scores_below_its_seed() {
        // Clip-free version of the pareto warm-start floor: seed the
        // anneal with a known-good design and check the result's
        // throughput is at least the seed's (the seed is recorded as the
        // initial best before any proposal).
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cfg = AnnealConfig::quick();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.resources,
            board.clock_hz,
        );
        let cold = anneal(&p, &cfg);
        assert!(cold.feasible);
        let warm_cfg = AnnealConfig {
            restarts: 1,
            ..cfg.clone()
        };
        let warm = anneal_seeded(&p, &warm_cfg, &cold.mapping);
        assert!(warm.feasible, "a feasible seed must stay feasible");
        assert!(
            warm.throughput >= cold.throughput,
            "seeded anneal fell below its seed: {} < {}",
            warm.throughput,
            cold.throughput
        );
    }

    #[test]
    fn seeded_anneal_deterministic_and_counts_acceptances() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cfg = AnnealConfig::quick();
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.budget(0.4),
            board.clock_hz,
        );
        let seed = anneal(&p, &cfg);
        let a = anneal_seeded(&p, &cfg, &seed.mapping);
        let b = anneal_seeded(&p, &cfg, &seed.mapping);
        assert_eq!(a.ii, b.ii);
        assert_eq!(a.resources, b.resources);
        assert_eq!(a.mapping.foldings, b.mapping.foldings);
        assert_eq!(a.accepted, b.accepted);
        assert!(a.accepted <= a.iterations_run);
        assert!(seed.accepted <= seed.iterations_run);
        // The quick schedule on this net always accepts something.
        assert!(seed.accepted > 0);
    }

    #[test]
    fn bigger_budget_never_worse() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cfg = AnnealConfig::quick();
        let small = anneal(
            &Problem::baseline(Cdfg::lower_baseline(&net), board.budget(0.2), board.clock_hz),
            &cfg,
        );
        let large = anneal(
            &Problem::baseline(Cdfg::lower_baseline(&net), board.budget(1.0), board.clock_hz),
            &cfg,
        );
        // SA is stochastic but with the same schedule the larger budget
        // must not lose by more than noise; enforce the strong form since
        // seeds are fixed.
        assert!(large.throughput >= small.throughput * 0.95);
    }
}
