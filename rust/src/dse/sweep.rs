//! Budget sweeps: run the annealer at a ladder of resource fractions to
//! trace a stage's Throughput-Area Pareto set (§IV-A: "Both the ATHEENA
//! optimizer and baseline optimizer are provided the board resources
//! constrained at different percentages in order to generate a
//! Throughput-Area Pareto curve ... they are run ten times and the best
//! points are chosen").
//!
//! A sweep is *planned* into independent [`SweepTask`]s (one anneal per
//! budget fraction, each with its own derived seed), executed either
//! sequentially or on scoped worker threads, and *assembled* back into a
//! TAP curve. Because each anneal depends only on its (problem, config)
//! pair and results are re-ordered by task index, the parallel path is
//! bit-identical to the sequential one — the pipeline's `Curves` stage
//! relies on this to parallelize the toolflow's dominant cost.

use super::annealer::{anneal, AnnealConfig, AnnealResult};
use super::problem::{Problem, ProblemKind};
use crate::ir::Cdfg;
use crate::resources::Board;
use crate::tap::{TapCurve, TapPoint};

#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Board-resource fractions to constrain the optimizer at.
    pub fractions: Vec<f64>,
    pub anneal: AnnealConfig,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            fractions: vec![0.15, 0.2, 0.25, 0.3, 0.4, 0.5, 0.6, 0.7, 0.85, 1.0],
            anneal: AnnealConfig::default(),
        }
    }
}

impl SweepConfig {
    pub fn quick() -> SweepConfig {
        SweepConfig {
            fractions: vec![0.25, 0.5, 1.0],
            anneal: AnnealConfig::quick(),
        }
    }
}

/// One independent anneal of a planned sweep: a problem at one budget
/// fraction with its derived seed.
#[derive(Clone, Debug)]
pub struct SweepTask {
    pub kind: ProblemKind,
    /// Index into the sweep's fraction ladder (drives seed derivation).
    pub fraction_index: usize,
    pub fraction: f64,
    pub problem: Problem,
    pub config: AnnealConfig,
}

/// Plan one sweep into its independent anneal tasks. Seeds follow the
/// `seed + i * 7919` scheme so every fraction's search is decorrelated
/// yet fully determined by the sweep config.
pub fn plan_sweep(
    kind: ProblemKind,
    cdfg: &Cdfg,
    board: &Board,
    cfg: &SweepConfig,
) -> Vec<SweepTask> {
    cfg.fractions
        .iter()
        .enumerate()
        .map(|(i, &frac)| {
            let budget = board.budget(frac);
            let problem = Problem::for_kind(kind, cdfg.clone(), budget, board.clock_hz);
            let mut config = cfg.anneal.clone();
            config.seed = cfg.anneal.seed.wrapping_add(i as u64 * 7919);
            SweepTask {
                kind,
                fraction_index: i,
                fraction: frac,
                problem,
                config,
            }
        })
        .collect()
}

/// Assemble per-fraction anneal results (in ladder order) into the TAP
/// curve (feasible points only) plus the raw results for realization.
pub fn assemble_sweep(
    cfg: &SweepConfig,
    results: Vec<AnnealResult>,
) -> (TapCurve, Vec<AnnealResult>) {
    debug_assert_eq!(results.len(), cfg.fractions.len());
    let mut points = Vec::new();
    for (i, r) in results.iter().enumerate() {
        if r.feasible {
            points.push(TapPoint {
                resources: r.resources,
                throughput: r.throughput,
                ii: r.ii,
                budget_fraction: cfg.fractions[i],
                source: i,
            });
        }
    }
    (TapCurve::from_points(points), results)
}

/// Run planned tasks on the deterministic executor
/// ([`util::exec::run_ordered`](crate::util::exec::run_ordered)),
/// returning results in task order. Task order — not completion order —
/// keeps the output bit-identical to a sequential run. Anneals invoked
/// from these workers run their restarts sequentially (the executor's
/// nesting rule), so the thread count stays bounded by the machine's
/// parallelism.
pub fn run_tasks_parallel(tasks: &[SweepTask]) -> Vec<AnnealResult> {
    crate::util::exec::run_ordered(tasks.len(), |i| {
        anneal(&tasks[i].problem, &tasks[i].config)
    })
}

/// Sweep one problem kind over the budget ladder sequentially, returning
/// the TAP curve (feasible points only) plus every raw annealer result.
pub fn sweep_budgets(
    kind: ProblemKind,
    cdfg: &Cdfg,
    board: &Board,
    cfg: &SweepConfig,
) -> (TapCurve, Vec<AnnealResult>) {
    let tasks = plan_sweep(kind, cdfg, board, cfg);
    let results = tasks
        .iter()
        .map(|t| anneal(&t.problem, &t.config))
        .collect();
    assemble_sweep(cfg, results)
}

/// Parallel variant of [`sweep_budgets`]: same curve, computed on scoped
/// threads (one anneal per fraction).
pub fn sweep_budgets_parallel(
    kind: ProblemKind,
    cdfg: &Cdfg,
    board: &Board,
    cfg: &SweepConfig,
) -> (TapCurve, Vec<AnnealResult>) {
    let tasks = plan_sweep(kind, cdfg, board, cfg);
    let results = run_tasks_parallel(&tasks);
    assemble_sweep(cfg, results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;

    #[test]
    fn sweep_produces_monotone_pareto() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cdfg = Cdfg::lower_baseline(&net);
        let (curve, raw) = sweep_budgets(
            ProblemKind::Baseline,
            &cdfg,
            &board,
            &SweepConfig::quick(),
        );
        assert!(!curve.points.is_empty());
        assert_eq!(raw.len(), 3);
        // Pareto: throughput non-decreasing when sorted by DSP usage.
        let pts = &curve.points;
        for w in pts.windows(2) {
            assert!(w[1].throughput >= w[0].throughput);
        }
    }

    #[test]
    fn stage2_sweep_runs() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cdfg = Cdfg::lower(&net, 8);
        let (curve, _) =
            sweep_budgets(ProblemKind::Stage(1), &cdfg, &board, &SweepConfig::quick());
        assert!(!curve.points.is_empty());
    }

    #[test]
    fn parallel_sweep_bit_identical_to_sequential() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cfg = SweepConfig::quick();
        for (kind, cdfg) in [
            (ProblemKind::Baseline, Cdfg::lower_baseline(&net)),
            (ProblemKind::Stage(0), Cdfg::lower(&net, 1)),
            (ProblemKind::Stage(1), Cdfg::lower(&net, 1)),
        ] {
            let (seq_curve, seq_raw) = sweep_budgets(kind, &cdfg, &board, &cfg);
            let (par_curve, par_raw) = sweep_budgets_parallel(kind, &cdfg, &board, &cfg);
            assert_eq!(seq_curve.points.len(), par_curve.points.len());
            for (a, b) in seq_curve.points.iter().zip(&par_curve.points) {
                assert_eq!(a.resources, b.resources);
                assert_eq!(a.ii, b.ii);
                assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
                assert_eq!(a.budget_fraction.to_bits(), b.budget_fraction.to_bits());
                assert_eq!(a.source, b.source);
            }
            for (a, b) in seq_raw.iter().zip(&par_raw) {
                assert_eq!(a.ii, b.ii);
                assert_eq!(a.resources, b.resources);
                assert_eq!(a.feasible, b.feasible);
                assert_eq!(a.mapping.foldings, b.mapping.foldings);
            }
        }
    }
}
