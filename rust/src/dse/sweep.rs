//! Budget sweeps: run the annealer at a ladder of resource fractions to
//! trace a stage's Throughput-Area Pareto set (§IV-A: "Both the ATHEENA
//! optimizer and baseline optimizer are provided the board resources
//! constrained at different percentages in order to generate a
//! Throughput-Area Pareto curve ... they are run ten times and the best
//! points are chosen").

use super::annealer::{anneal, AnnealConfig, AnnealResult};
use super::problem::{Problem, ProblemKind};
use crate::ir::Cdfg;
use crate::resources::Board;
use crate::tap::{TapCurve, TapPoint};

#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Board-resource fractions to constrain the optimizer at.
    pub fractions: Vec<f64>,
    pub anneal: AnnealConfig,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            fractions: vec![0.15, 0.2, 0.25, 0.3, 0.4, 0.5, 0.6, 0.7, 0.85, 1.0],
            anneal: AnnealConfig::default(),
        }
    }
}

impl SweepConfig {
    pub fn quick() -> SweepConfig {
        SweepConfig {
            fractions: vec![0.25, 0.5, 1.0],
            anneal: AnnealConfig::quick(),
        }
    }
}

/// Sweep one problem kind over the budget ladder, returning the TAP curve
/// (feasible points only) plus every raw annealer result for reporting.
pub fn sweep_budgets(
    kind: ProblemKind,
    cdfg: &Cdfg,
    board: &Board,
    cfg: &SweepConfig,
) -> (TapCurve, Vec<AnnealResult>) {
    let mut results = Vec::new();
    let mut points = Vec::new();
    for (i, &frac) in cfg.fractions.iter().enumerate() {
        let budget = board.budget(frac);
        let problem = match kind {
            ProblemKind::Baseline => Problem::baseline(cdfg.clone(), budget, board.clock_hz),
            ProblemKind::Stage1 => Problem::stage1(cdfg.clone(), budget, board.clock_hz),
            ProblemKind::Stage2 => Problem::stage2(cdfg.clone(), budget, board.clock_hz),
        };
        let mut acfg = cfg.anneal.clone();
        acfg.seed = cfg.anneal.seed.wrapping_add(i as u64 * 7919);
        let r = anneal(&problem, &acfg);
        if r.feasible {
            points.push(TapPoint {
                resources: r.resources,
                throughput: r.throughput,
                ii: r.ii,
                budget_fraction: frac,
                source: results.len(),
            });
        }
        results.push(r);
    }
    (TapCurve::from_points(points), results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::network::testnet;

    #[test]
    fn sweep_produces_monotone_pareto() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cdfg = Cdfg::lower_baseline(&net);
        let (curve, raw) = sweep_budgets(
            ProblemKind::Baseline,
            &cdfg,
            &board,
            &SweepConfig::quick(),
        );
        assert!(!curve.points.is_empty());
        assert_eq!(raw.len(), 3);
        // Pareto: throughput non-decreasing when sorted by DSP usage.
        let pts = &curve.points;
        for w in pts.windows(2) {
            assert!(w[1].throughput >= w[0].throughput);
        }
    }

    #[test]
    fn stage2_sweep_runs() {
        let net = testnet::blenet_like();
        let board = Board::zc706();
        let cdfg = Cdfg::lower(&net, 8);
        let (curve, _) =
            sweep_budgets(ProblemKind::Stage2, &cdfg, &board, &SweepConfig::quick());
        assert!(!curve.points.is_empty());
    }
}
