//! Design-space exploration — fpgaConvNet's simulated-annealing optimizer,
//! extended with ATHEENA's per-stage problems (§III-B: "Modifications to
//! the parser and optimizer are made to ... encompass the control-flow").
//!
//! * [`problem`]  — what is being optimized: a node subset of a CDFG with
//!                  a resource budget and an [`Objective`] (maximize
//!                  throughput, minimize area at a throughput target, or
//!                  trace the frontier),
//! * [`annealer`] — the simulated-annealing search over foldings with an
//!                  objective-aware energy,
//! * [`sweep`]    — budget sweeps producing Throughput-Area Pareto points,
//! * [`pareto`]   — budget-*scaling* sweeps producing the throughput/area
//!                  frontier, the resource-matched lookup, and the
//!                  area-minimizing search (the paper's "46% of the
//!                  resources" claim),
//! * [`exact`]    — the certified optimization layer (DESIGN.md §13): a
//!                  deterministic branch-and-bound oracle returning
//!                  provably optimal mappings for size-bounded problems,
//!                  with seeded certification producing the per-design
//!                  optimality gap `atheena pareto --certify` reports.

pub mod annealer;
pub mod baselines;
pub mod exact;
pub mod pareto;
pub mod problem;
pub mod sweep;

pub use annealer::{
    anneal, anneal_call_count, anneal_seeded, anneal_sequential, AnnealConfig, AnnealResult,
};
pub use baselines::{greedy, naive_combine, random_search};
pub use exact::{
    certify, certify_result, exact, exact_exhaustive, exact_seeded, CertifiedGap, ExactConfig,
    ExactOutcome, ExactResult, SeededOutcome,
};
pub use pareto::{
    assemble_frontier, min_area_design, plan_frontier, solve, sweep_frontier,
    sweep_frontier_sequential, FrontierPoint, ObjectiveOutcome, ParetoConfig,
    ParetoFrontier, Solution, WarmStart,
};
pub use problem::{Objective, Problem, ProblemKind};
pub use sweep::{
    assemble_sweep, plan_sweep, run_tasks_parallel, sweep_budgets, sweep_budgets_parallel,
    SweepConfig, SweepTask,
};
