//! Design-space exploration — fpgaConvNet's simulated-annealing optimizer,
//! extended with ATHEENA's per-stage problems (§III-B: "Modifications to
//! the parser and optimizer are made to ... encompass the control-flow").
//!
//! * [`problem`]  — what is being optimized: a node subset of a CDFG with
//!                  an II objective and a resource budget,
//! * [`annealer`] — the simulated-annealing search over foldings,
//! * [`sweep`]    — budget sweeps producing Throughput-Area Pareto points.

pub mod annealer;
pub mod baselines;
pub mod problem;
pub mod sweep;

pub use annealer::{
    anneal, anneal_call_count, anneal_sequential, AnnealConfig, AnnealResult,
};
pub use baselines::{greedy, naive_combine, random_search};
pub use problem::{Problem, ProblemKind};
pub use sweep::{
    assemble_sweep, plan_sweep, run_tasks_parallel, sweep_budgets, sweep_budgets_parallel,
    SweepConfig, SweepTask,
};
