//! Deterministic pseudo-random number generation (no external crates).
//!
//! The simulated-annealing optimizer, the workload generators, and the
//! property-test harness all need seedable, reproducible randomness. This
//! is `xoshiro256**` (Blackman & Vigna) seeded through SplitMix64 — the
//! same construction `rand`'s small RNGs use — hand-rolled because the
//! build is fully offline (see `.cargo/config.toml`).

/// SplitMix64 step; used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// `xoshiro256**` PRNG. Not cryptographic; excellent for simulation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so similar seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless bounded sampling.
        let n = n as u64;
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = (self.next_u64() as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform selection from a slice.
    #[inline]
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 8];
        let n = 80_000;
        for _ in 0..n {
            counts[r.below(8)] += 1;
        }
        for &c in &counts {
            let expect = n / 8;
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "bucket count {c} too far from {expect}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 30);
    }
}
