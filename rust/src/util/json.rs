//! Minimal, dependency-free JSON parser + writer.
//!
//! The toolflow's interchange files (`artifacts/networks/*.json`,
//! `artifacts/meta.json`, HLS design manifests) are JSON. The build is
//! fully offline with no `serde`/`serde_json` in the vendored crate set,
//! so this module implements the subset of RFC 8259 we need: objects,
//! arrays, strings (with escapes), numbers, booleans, null. Numbers are
//! held as f64 (every value we exchange fits exactly or is a measurement).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use BTreeMap for deterministic iteration
/// (design manifests are diffed in tests).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with the key name (for parse-time
    /// validation of network files).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing field '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- constructors --------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- serialization --------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parser ---------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 from the source slice.
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_bool(),
            Some(false)
        );
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"name":"blenet","p":0.25,"shape":[1,28,28],"ok":true,"nil":null}"#;
        let v = parse(src).unwrap();
        for text in [v.to_string_pretty(), v.to_string_compact()] {
            assert_eq!(parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
        let v = Json::Str("π≈3".into());
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "\"x", "{\"a\":}", "12x", "{a:1}"] {
            assert!(parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn integers_roundtrip_exactly() {
        let v = parse("[0, 218600, 437200, 900, 1090]").unwrap();
        assert_eq!(v.to_string_compact(), "[0,218600,437200,900,1090]");
    }
}
