//! Tiny property-testing harness (the vendored crate set has no
//! `proptest`, so this provides the subset we use: seeded case generation,
//! configurable case counts, and failure reporting with the seed needed to
//! reproduce).
//!
//! Usage:
//! ```ignore
//! check(200, |r| {
//!     let n = r.below(64) + 1;
//!     let v = gen_vec(r, n, |r| r.f64());
//!     prop_assert(v.len() == n, "length preserved")
//! });
//! ```

use super::rng::Rng;

/// Result of a single property case: Ok(()) or a failure description.
pub type PropResult = Result<(), String>;

/// Assert helper that returns a `PropResult` instead of panicking, so the
/// harness can attach the failing seed.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Approximate float equality (relative + absolute tolerance).
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

/// Run `cases` seeded property cases. Panics with the case seed on failure
/// so the exact case can be re-run under a debugger.
pub fn check<F: FnMut(&mut Rng) -> PropResult>(cases: u64, mut f: F) {
    // Base seed can be pinned for reproduction: ATHEENA_PROP_SEED=n.
    let base = std::env::var("ATHEENA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA7EE_4A00u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property failed on case {case} (ATHEENA_PROP_SEED={base}, \
                 case seed {seed}): {msg}"
            );
        }
    }
}

/// Generate a vector of `n` items.
pub fn gen_vec<T, F: FnMut(&mut Rng) -> T>(
    rng: &mut Rng,
    n: usize,
    mut f: F,
) -> Vec<T> {
    (0..n).map(|_| f(rng)).collect()
}

/// Random usize in [lo, hi] inclusive.
pub fn gen_range(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check(50, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failure() {
        check(10, |r| prop_assert(r.f64() < 0.5, "coin flip"));
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0));
        assert!(!close(1.0, 1.1, 1e-6, 1e-6));
        assert!(close(0.0, 1e-9, 0.0, 1e-6));
    }
}
