//! Offline-build utilities: PRNG, JSON, tiny property-testing harness.

pub mod bench;
pub mod json;
pub mod proptest;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
