//! Offline-build utilities: PRNG, JSON, deterministic parallel
//! executor, tiny property-testing harness.

pub mod bench;
pub mod exec;
pub mod json;
pub mod proptest;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
