//! Deterministic scoped-thread executor — the crate-wide parallelism
//! primitive behind every hot loop (TAP sweeps, anneal restarts, the
//! operating-envelope q-grid, drift-window statistics, profiler split
//! statistics).
//!
//! Contract
//! --------
//! [`run_ordered`] executes `n` independent tasks and returns their
//! results **in task order**, so a parallel run is bit-identical to the
//! sequential `(0..n).map(task).collect()` as long as each task is a
//! pure function of its index (no shared mutable state, no RNG sharing
//! across tasks). Workers drain a shared atomic counter, so scheduling
//! is dynamic but the *output* never depends on it.
//!
//! Nesting: a task that itself calls into the executor (e.g. an anneal
//! whose restarts are parallelized, invoked from a parallel sweep) runs
//! its inner tasks sequentially on the calling worker instead of
//! spawning a second generation of threads. This keeps the thread count
//! bounded by `available_parallelism` without changing any result —
//! sequential execution is always a legal schedule.
//!
//! [`run_ordered_with`] additionally gives every worker a private,
//! lazily-created scratch state (e.g. a
//! [`SimScratch`](crate::sim::SimScratch)) reused across all tasks that
//! worker runs — the zero-allocation loop pattern. The state must not
//! influence results (it is scratch, not input), which each caller's
//! bit-identicality property test enforces.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    static IN_EXECUTOR: Cell<bool> = const { Cell::new(false) };
}

/// Whether the current thread is already an executor worker (nested
/// calls run sequentially).
pub fn in_executor_worker() -> bool {
    IN_EXECUTOR.with(|f| f.get())
}

/// Run `n` independent tasks, returning results in task order —
/// bit-identical to `(0..n).map(task).collect()`.
pub fn run_ordered<T, F>(n: usize, task: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    run_ordered_with(n, || (), |_, i| task(i))
}

/// [`run_ordered`] with a per-worker scratch state: `init` is called
/// once per worker (or once total on the sequential path) and the state
/// is threaded through every task that worker executes.
pub fn run_ordered_with<S, T, I, F>(n: usize, init: I, task: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(n);
    if workers <= 1 || n == 1 || in_executor_worker() {
        let mut state = init();
        return (0..n).map(|i| task(&mut state, i)).collect();
    }

    let next = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                IN_EXECUTOR.with(|f| f.set(true));
                let mut state = init();
                let mut local: Vec<(usize, T)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, task(&mut state, i)));
                }
                if !local.is_empty() {
                    done.lock().unwrap().append(&mut local);
                }
            });
        }
    });
    let mut done = done.into_inner().unwrap();
    debug_assert_eq!(done.len(), n, "every task must produce a result");
    done.sort_unstable_by_key(|&(i, _)| i);
    done.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn work(i: usize) -> u64 {
        // Deterministic, non-trivial per-index function.
        let mut x = i as u64 ^ 0x9E37_79B9;
        for _ in 0..8 {
            x = x.wrapping_mul(0x2545_F491_4F6C_DD1D).rotate_left(17) ^ i as u64;
        }
        x
    }

    #[test]
    fn zero_and_one_tasks() {
        let none: Vec<u64> = run_ordered(0, work);
        assert!(none.is_empty());
        let one = run_ordered(1, work);
        assert_eq!(one, vec![work(0)]);
    }

    #[test]
    fn many_more_tasks_than_cores_in_order() {
        // Tasks ≫ cores: results must land in task order, identical to
        // the sequential map.
        let n = 1009;
        let par = run_ordered(n, work);
        let seq: Vec<u64> = (0..n).map(work).collect();
        assert_eq!(par, seq);
    }

    #[test]
    fn per_worker_state_reused_without_changing_results() {
        // The scratch state must not leak into results: a worker-local
        // accumulator used as *scratch* (cleared per task) gives the same
        // answers as the stateless path.
        let n = 257;
        let with_state = run_ordered_with(
            n,
            Vec::<u64>::new,
            |buf, i| {
                buf.clear();
                buf.extend((0..=i as u64).map(|k| k * k));
                buf.iter().sum::<u64>()
            },
        );
        let stateless: Vec<u64> = (0..n)
            .map(|i| (0..=i as u64).map(|k| k * k).sum())
            .collect();
        assert_eq!(with_state, stateless);
    }

    #[test]
    fn nested_invocations_run_and_agree() {
        // A task that itself calls the executor: the inner call takes
        // the sequential path (no thread explosion) and the combined
        // output is identical to a fully sequential evaluation.
        let outer = 13;
        let inner = 37;
        let par = run_ordered(outer, |i| {
            run_ordered(inner, move |j| work(i * inner + j))
                .into_iter()
                .sum::<u64>()
        });
        let seq: Vec<u64> = (0..outer)
            .map(|i| (0..inner).map(|j| work(i * inner + j)).sum())
            .collect();
        assert_eq!(par, seq);
    }
}
