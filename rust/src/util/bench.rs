//! Criterion-lite: a minimal benchmarking harness (the offline vendored
//! crate set has no criterion). Provides warmup, repeated timed runs,
//! and mean/min/max reporting in a stable, grep-able format used by the
//! `benches/` targets and EXPERIMENTS.md §Perf.

use std::time::Instant;

#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn per_second(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs. The
/// closure's return value is black-boxed to keep the optimizer honest.
pub fn bench<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut() -> T,
) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = times.iter().sum::<f64>() / iters as f64;
    let min = times.iter().copied().fold(f64::INFINITY, f64::min);
    let max = times.iter().copied().fold(0.0f64, f64::max);
    let stats = BenchStats {
        iters,
        mean_ns: mean,
        min_ns: min,
        max_ns: max,
    };
    println!(
        "bench {name:<40} mean {:>12.3} ms  min {:>12.3} ms  max {:>12.3} ms  ({:.1}/s)",
        mean / 1e6,
        min / 1e6,
        max / 1e6,
        stats.per_second()
    );
    stats
}

/// Measure a single long-running operation (e.g. one full toolflow).
pub fn once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("bench {name:<40} once {secs:>12.3} s");
    (out, secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench("noop", 2, 10, || 42u64);
        assert_eq!(s.iters, 10);
        assert!(s.min_ns <= s.mean_ns && s.mean_ns <= s.max_ns);
    }

    #[test]
    fn once_returns_value() {
        let (v, secs) = once("quick", || 7);
        assert_eq!(v, 7);
        assert!(secs >= 0.0);
    }
}
