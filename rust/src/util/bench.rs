//! Criterion-lite: a minimal benchmarking harness (the offline vendored
//! crate set has no criterion). Provides warmup, repeated timed runs,
//! and mean/min/max reporting in a stable, grep-able format used by the
//! `benches/` targets and EXPERIMENTS.md §Perf.
//!
//! [`BenchLog`] wraps the same primitives and additionally records every
//! result, so a bench binary can persist its numbers as JSON
//! (`--save-json` in `bench_sim` / `bench_e2e` → `BENCH_sim.json` /
//! `BENCH_e2e.json`) — the machine-readable perf trajectory CI tracks.

use std::time::Instant;

use super::json::Json;

#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn per_second(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs. The
/// closure's return value is black-boxed to keep the optimizer honest.
pub fn bench<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut() -> T,
) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = times.iter().sum::<f64>() / iters as f64;
    let min = times.iter().copied().fold(f64::INFINITY, f64::min);
    let max = times.iter().copied().fold(0.0f64, f64::max);
    let stats = BenchStats {
        iters,
        mean_ns: mean,
        min_ns: min,
        max_ns: max,
    };
    println!(
        "bench {name:<40} mean {:>12.3} ms  min {:>12.3} ms  max {:>12.3} ms  ({:.1}/s)",
        mean / 1e6,
        min / 1e6,
        max / 1e6,
        stats.per_second()
    );
    stats
}

/// Measure a single long-running operation (e.g. one full toolflow).
pub fn once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("bench {name:<40} once {secs:>12.3} s");
    (out, secs)
}

/// Records every measurement it runs so the bench binary can persist a
/// JSON snapshot next to the human-readable output.
#[derive(Default)]
pub struct BenchLog {
    entries: Vec<(String, Json)>,
}

impl BenchLog {
    pub fn new() -> BenchLog {
        BenchLog::default()
    }

    /// [`bench`], recorded.
    pub fn bench<T>(
        &mut self,
        name: &str,
        warmup: usize,
        iters: usize,
        f: impl FnMut() -> T,
    ) -> BenchStats {
        let s = bench(name, warmup, iters, f);
        self.entries.push((
            name.to_string(),
            Json::obj(vec![
                ("iters", Json::num(s.iters as f64)),
                ("mean_ns", Json::Num(s.mean_ns)),
                ("min_ns", Json::Num(s.min_ns)),
                ("max_ns", Json::Num(s.max_ns)),
            ]),
        ));
        s
    }

    /// [`once`], recorded.
    pub fn once<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> (T, f64) {
        let (out, secs) = once(name, f);
        self.entries.push((
            name.to_string(),
            Json::obj(vec![("once_s", Json::Num(secs))]),
        ));
        (out, secs)
    }

    /// Write every recorded entry as one JSON object keyed by bench
    /// name.
    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        let doc = Json::Obj(self.entries.iter().cloned().collect());
        std::fs::write(path, doc.to_string_pretty())
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("bench json saved to {path}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench("noop", 2, 10, || 42u64);
        assert_eq!(s.iters, 10);
        assert!(s.min_ns <= s.mean_ns && s.mean_ns <= s.max_ns);
    }

    #[test]
    fn once_returns_value() {
        let (v, secs) = once("quick", || 7);
        assert_eq!(v, 7);
        assert!(secs >= 0.0);
    }

    #[test]
    fn bench_log_saves_json() {
        let mut log = BenchLog::new();
        log.bench("unit/a", 0, 3, || 1u64);
        let (v, _) = log.once("unit/b", || 2u64);
        assert_eq!(v, 2);
        let path = std::env::temp_dir().join(format!(
            "atheena-benchlog-{}.json",
            std::process::id()
        ));
        log.save(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(&text).unwrap();
        assert!(doc.get("unit/a").and_then(|e| e.get("mean_ns")).is_some());
        assert!(doc.get("unit/b").and_then(|e| e.get("once_s")).is_some());
        let _ = std::fs::remove_file(path);
    }
}
