//! Criterion-lite: a minimal benchmarking harness (the offline vendored
//! crate set has no criterion). Provides warmup, repeated timed runs,
//! and mean/min/max reporting in a stable, grep-able format used by the
//! `benches/` targets and EXPERIMENTS.md §Perf.
//!
//! [`BenchLog`] wraps the same primitives and additionally records every
//! result, so a bench binary can persist its numbers as JSON
//! (`--save-json` in `bench_sim` / `bench_e2e` → `BENCH_sim.json` /
//! `BENCH_e2e.json`) — the machine-readable perf trajectory CI tracks.

use std::time::Instant;

use super::json::Json;

#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn per_second(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs. The
/// closure's return value is black-boxed to keep the optimizer honest.
pub fn bench<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut() -> T,
) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = times.iter().sum::<f64>() / iters as f64;
    let min = times.iter().copied().fold(f64::INFINITY, f64::min);
    let max = times.iter().copied().fold(0.0f64, f64::max);
    let stats = BenchStats {
        iters,
        mean_ns: mean,
        min_ns: min,
        max_ns: max,
    };
    println!(
        "bench {name:<40} mean {:>12.3} ms  min {:>12.3} ms  max {:>12.3} ms  ({:.1}/s)",
        mean / 1e6,
        min / 1e6,
        max / 1e6,
        stats.per_second()
    );
    stats
}

/// Measure a single long-running operation (e.g. one full toolflow).
pub fn once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("bench {name:<40} once {secs:>12.3} s");
    (out, secs)
}

/// Records every measurement it runs so the bench binary can persist a
/// JSON snapshot next to the human-readable output.
#[derive(Default)]
pub struct BenchLog {
    entries: Vec<(String, Json)>,
}

impl BenchLog {
    pub fn new() -> BenchLog {
        BenchLog::default()
    }

    /// [`bench`], recorded.
    pub fn bench<T>(
        &mut self,
        name: &str,
        warmup: usize,
        iters: usize,
        f: impl FnMut() -> T,
    ) -> BenchStats {
        let s = bench(name, warmup, iters, f);
        self.entries.push((
            name.to_string(),
            Json::obj(vec![
                ("iters", Json::num(s.iters as f64)),
                ("mean_ns", Json::Num(s.mean_ns)),
                ("min_ns", Json::Num(s.min_ns)),
                ("max_ns", Json::Num(s.max_ns)),
            ]),
        ));
        s
    }

    /// [`once`], recorded.
    pub fn once<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> (T, f64) {
        let (out, secs) = once(name, f);
        self.entries.push((
            name.to_string(),
            Json::obj(vec![("once_s", Json::Num(secs))]),
        ));
        (out, secs)
    }

    /// Record a derived, higher-is-better metric (samples/s,
    /// proposals/s, runs/s…) so later runs can be regression-checked
    /// against this one via [`BenchLog::check_against`].
    pub fn metric(&mut self, name: &str, value: f64, unit: &str) {
        println!("bench {name:<40} metric {value:>14.1} {unit}");
        self.entries.push((
            name.to_string(),
            Json::obj(vec![
                ("metric", Json::Num(value)),
                ("unit", Json::str(unit)),
            ]),
        ));
    }

    /// Write every recorded entry as one JSON object keyed by bench
    /// name, **merged** into any entries already present at `path`
    /// (same-name entries are replaced, others survive) — so multiple
    /// bench binaries can share one trajectory file and committed
    /// baselines keep keys a given binary does not produce.
    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        let mut map = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| super::json::parse(&text).ok())
            .and_then(|doc| match doc {
                Json::Obj(map) => Some(map),
                _ => None,
            })
            .unwrap_or_default();
        for (name, entry) in &self.entries {
            map.insert(name.clone(), entry.clone());
        }
        let doc = Json::Obj(map);
        std::fs::write(path, doc.to_string_pretty())
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("bench json saved to {path}");
        Ok(())
    }

    /// Compare this run's `metric` entries against a previously saved
    /// baseline at `path`: any shared metric more than `tolerance`
    /// (fraction, e.g. 0.25) below the baseline value is a regression
    /// and fails the check. Metrics present in only one of the two runs
    /// are skipped, so fresh baselines bootstrap gracefully.
    pub fn check_against(&self, path: &str, tolerance: f64) -> anyhow::Result<()> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading bench baseline {path}: {e}"))?;
        let doc = super::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing bench baseline {path}: {e}"))?;
        let mut checked = 0usize;
        let mut regressions = Vec::new();
        for (name, entry) in &self.entries {
            let Some(cur) = entry.get("metric").and_then(Json::as_f64) else {
                continue;
            };
            let Some(base) = doc
                .get(name)
                .and_then(|e| e.get("metric"))
                .and_then(Json::as_f64)
            else {
                continue;
            };
            checked += 1;
            if cur < base * (1.0 - tolerance) {
                regressions.push(format!(
                    "{name}: {cur:.1} vs baseline {base:.1} ({:.0}% drop)",
                    (1.0 - cur / base) * 100.0
                ));
            }
        }
        anyhow::ensure!(
            regressions.is_empty(),
            "bench regression vs {path}:\n  {}",
            regressions.join("\n  ")
        );
        println!(
            "bench check vs {path}: {checked} shared metric(s), none regressed >{:.0}%",
            tolerance * 100.0
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench("noop", 2, 10, || 42u64);
        assert_eq!(s.iters, 10);
        assert!(s.min_ns <= s.mean_ns && s.mean_ns <= s.max_ns);
    }

    #[test]
    fn once_returns_value() {
        let (v, secs) = once("quick", || 7);
        assert_eq!(v, 7);
        assert!(secs >= 0.0);
    }

    #[test]
    fn metric_merge_save_and_regression_check() {
        let path = std::env::temp_dir().join(format!(
            "atheena-benchmetric-{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();

        let mut baseline = BenchLog::new();
        baseline.metric("unit/throughput", 1000.0, "samples/s");
        baseline.metric("unit/only-in-baseline", 5.0, "x/s");
        baseline.save(&path).unwrap();

        // A faster run passes; merge-save keeps the baseline-only key.
        let mut fast = BenchLog::new();
        fast.metric("unit/throughput", 1200.0, "samples/s");
        fast.metric("unit/only-in-current", 7.0, "x/s");
        fast.check_against(&path, 0.25).unwrap();
        fast.save(&path).unwrap();
        let doc = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap())
            .unwrap();
        assert!(doc.get("unit/only-in-baseline").is_some(), "merge keeps old keys");
        assert!(doc.get("unit/only-in-current").is_some());
        assert_eq!(
            doc.get("unit/throughput")
                .and_then(|e| e.get("metric"))
                .and_then(Json::as_f64),
            Some(1200.0)
        );

        // A >25% drop is a regression.
        let mut slow = BenchLog::new();
        slow.metric("unit/throughput", 100.0, "samples/s");
        assert!(slow.check_against(&path, 0.25).is_err());
        // Within tolerance passes.
        let mut ok = BenchLog::new();
        ok.metric("unit/throughput", 950.0, "samples/s");
        ok.check_against(&path, 0.25).unwrap();

        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bench_log_saves_json() {
        let mut log = BenchLog::new();
        log.bench("unit/a", 0, 3, || 1u64);
        let (v, _) = log.once("unit/b", || 2u64);
        assert_eq!(v, 2);
        let path = std::env::temp_dir().join(format!(
            "atheena-benchlog-{}.json",
            std::process::id()
        ));
        log.save(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(&text).unwrap();
        assert!(doc.get("unit/a").and_then(|e| e.get("mean_ns")).is_some());
        assert!(doc.get("unit/b").and_then(|e| e.get("once_s")).is_some());
        let _ = std::fs::remove_file(path);
    }
}
