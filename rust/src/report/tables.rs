//! Table regeneration: Tables I–IV of §IV, plus the Fig. 9/10-style
//! throughput/area frontier table (`report pareto`) rendered straight
//! from the frontier persisted in the design artifact, and the
//! `atheena trace` aggregation table rendered from a
//! [`TraceSummary`](crate::trace::TraceSummary).

use std::fmt::Write as _;

use super::context::ReportContext;
use crate::coordinator::batch::{BatchHost, BaselineHost};
use crate::coordinator::pipeline::DesignFrontier;
use crate::coordinator::toolflow::{BaselineDesign, ChosenDesign};
use crate::resources::Board;
use crate::runtime::ArtifactStore;
use crate::sim::DesignTiming;
use crate::trace::TraceSummary;

/// Pick three representative design points (low/mid/high budget) from a
/// list sorted by budget fraction — the paper's B1–B3 / A1–A3.
fn pick3<T>(xs: &[T]) -> Vec<&T> {
    match xs.len() {
        0 => vec![],
        1 => vec![&xs[0]],
        2 => vec![&xs[0], &xs[1]],
        n => vec![&xs[n / 4], &xs[n / 2], &xs[n - 1]],
    }
}

/// Render the Fig. 9/10-style throughput/area frontier table: the
/// baseline and EE Pareto fronts (area = limiting-resource fraction of
/// the board) plus the paper's headline resource-matched line at the
/// given throughput `slack` (0.05 = "within 5% of the baseline's
/// best"). Pure function of the persisted [`DesignFrontier`] —
/// golden-tested byte-for-byte in `tests/integration.rs`.
///
/// When any point carries a certified optimality gap (an
/// `atheena pareto --certify` run, DESIGN.md §13) a "% of certified
/// optimum" column is appended; uncertified frontiers render exactly as
/// before, keeping the pre-certification goldens byte-identical.
pub fn render_frontier(f: &DesignFrontier, board_name: &str, slack: f64) -> String {
    let certified = f
        .baseline
        .points
        .iter()
        .chain(f.ee.points.iter())
        .any(|p| p.gap_pct.is_some());
    let mut s = String::new();
    let _ = writeln!(
        s,
        "== Pareto frontier: throughput vs area, {board_name} =="
    );
    for (title, front) in [
        ("baseline (fpgaConvNet)", &f.baseline),
        ("ATHEENA early-exit", &f.ee),
    ] {
        let _ = writeln!(s, "-- {title} --");
        let _ = write!(
            s,
            "{:>8} {:>10} {:>8} {:>8} {:>16}",
            "budget%", "LUT", "DSP", "area%", "thr(samples/s)"
        );
        if certified {
            let _ = write!(s, " {:>9}", "%cert-opt");
        }
        let _ = writeln!(s);
        for p in &front.points {
            let _ = write!(
                s,
                "{:>8.0} {:>10} {:>8} {:>8.1} {:>16.0}",
                p.budget_fraction * 100.0,
                p.resources.lut,
                p.resources.dsp,
                p.utilization * 100.0,
                p.throughput
            );
            if certified {
                match p.gap_pct {
                    Some(g) => {
                        let _ = write!(s, " {:>9.2}", 100.0 - g);
                    }
                    None => {
                        let _ = write!(s, " {:>9}", "-");
                    }
                }
            }
            let _ = writeln!(s);
        }
    }
    let keep = (1.0 - slack) * 100.0;
    match f.resource_matched(slack) {
        Some(m) => {
            let _ = writeln!(
                s,
                "resource-matched: EE reaches {:.0} samples/s (>= {keep:.0}% of baseline max \
                 {:.0}) at {:.1}% board area = {:.0}% of the baseline's area",
                m.ee.throughput,
                m.baseline.throughput,
                m.ee.utilization * 100.0,
                m.fraction * 100.0
            );
        }
        None => {
            let _ = writeln!(
                s,
                "resource-matched: no EE design reaches {keep:.0}% of the baseline max"
            );
        }
    }
    s
}

/// Render the `atheena trace` aggregation table: per-exit latency
/// distributions (ticks and µs at the producer clock), per-buffer
/// stall/residency totals, and the closed-loop reconvergence span.
/// Pure function of the [`TraceSummary`] — golden-tested
/// byte-for-byte in `tests/trace_props.rs`.
pub fn render_trace_summary(t: &TraceSummary) -> String {
    let mut s = String::new();
    let us = |ticks: f64| ticks * 1e6 / t.clock_hz;
    let _ = writeln!(
        s,
        "== Trace summary: {} samples at {:.1} MHz ==",
        t.samples,
        t.clock_hz / 1e6
    );
    if t.dropped_events > 0 {
        let _ = writeln!(
            s,
            "(recorder ring evicted {} oldest events; head of the run is missing)",
            t.dropped_events
        );
    }
    let _ = writeln!(s, "-- per-exit latency (admission -> retirement, ticks) --");
    let _ = writeln!(
        s,
        "{:>5} {:>8} {:>7} {:>9} {:>11} {:>9} {:>9} {:>9} {:>10}",
        "exit", "count", "rate%", "min", "mean", "p50", "p99", "max", "mean(us)"
    );
    for e in &t.exits {
        let _ = writeln!(
            s,
            "{:>5} {:>8} {:>7.1} {:>9} {:>11.1} {:>9} {:>9} {:>9} {:>10.2}",
            e.stage,
            e.count,
            e.rate * 100.0,
            e.min,
            e.mean,
            e.p50,
            e.p99,
            e.max,
            us(e.mean)
        );
    }
    for e in &t.exits {
        let hist: Vec<String> = e.histogram.iter().map(|c| c.to_string()).collect();
        let _ = writeln!(
            s,
            "  exit {} latency histogram (log2 ticks): [{}]",
            e.stage,
            hist.join(", ")
        );
    }
    if !t.buffers.is_empty() {
        let _ = writeln!(s, "-- conditional buffers --");
        let _ = writeln!(
            s,
            "{:>7} {:>8} {:>13} {:>9} {:>9} {:>13} {:>9}",
            "buffer", "stalls", "stall_cycles", "drained", "dropped", "max_resident", "peak_occ"
        );
        for b in &t.buffers {
            let _ = writeln!(
                s,
                "{:>7} {:>8} {:>13} {:>9} {:>9} {:>13} {:>9}",
                b.buffer,
                b.stall_events,
                b.stall_cycles,
                b.drained,
                b.dropped,
                b.max_residency,
                b.peak_occupancy
            );
        }
    }
    if t.control.windows > 0 {
        let c = &t.control;
        let _ = writeln!(s, "-- closed-loop control --");
        let _ = writeln!(
            s,
            "  windows {} | retunes {} | mean window throughput {:.0} samples/s",
            c.windows, c.retunes, c.mean_throughput_sps
        );
        match (c.first_retune_window, c.reconverge_ticks, c.reconverge_windows) {
            (Some(fw), Some(ticks), Some(wins)) => {
                let _ = writeln!(
                    s,
                    "  first retune at window {fw}; reconverged over {wins} windows ({ticks} ticks = {:.1} us)",
                    us(ticks as f64)
                );
            }
            _ => {
                let _ = writeln!(s, "  no retunes observed (thresholds held steady)");
            }
        }
    }
    // Degradation section (DESIGN.md §12): omitted entirely on a clean
    // run so fault-free summaries stay byte-identical to the goldens.
    if !t.degradation.is_clean() {
        let d = &t.degradation;
        let _ = writeln!(s, "-- degradation --");
        let _ = writeln!(
            s,
            "  shed {} | forced exits {} | worker stalls {} ({} ms) | restarts {}",
            d.shed, d.forced_exits, d.worker_stalls, d.stall_millis, d.worker_restarts
        );
    }
    s
}

/// `report pareto` — the throughput/area frontier of the cached B-LeNet
/// artifact (zero anneal calls on a warm design cache: the frontier is
/// persisted with the artifact).
pub fn pareto(ctx: &mut ReportContext) -> anyhow::Result<()> {
    let board = Board::zc706();
    let r = ctx.toolflow("blenet", board.clone())?;
    print!("{}", render_frontier(&r.frontier, board.name, 0.05));
    Ok(())
}

/// Table I — resource comparison, implemented baseline vs ATHEENA.
pub fn table1(ctx: &mut ReportContext) -> anyhow::Result<()> {
    let board = Board::zc706();
    let r = ctx.toolflow("blenet", board.clone())?;
    println!("== Table I: implemented Baseline vs ATHEENA, B-LeNet on ZC706 ==");
    println!(
        "{:>4} {:>9} {:>9} {:>6} {:>6} {:>10} {:>16}",
        "", "LUT", "FF", "DSP", "BRAM", "limit(%)", "thr(samples/s)"
    );
    let bases: Vec<&BaselineDesign> = pick3(&r.baseline_designs);
    let ees: Vec<&ChosenDesign> = pick3(&r.designs);
    for (i, (b, a)) in bases.iter().zip(ees.iter()).enumerate() {
        let (bk, bf) = b.total_resources.limiting(&board.resources);
        println!(
            "B{:<3} {:>9} {:>9} {:>6} {:>6} {:>5} {:>3.0}% {:>16.0}",
            i + 1,
            b.total_resources.lut,
            b.total_resources.ff,
            b.total_resources.dsp,
            b.total_resources.bram,
            bk.to_string(),
            bf * 100.0,
            b.measured.throughput_sps
        );
        let (ak, af) = a.total_resources.limiting(&board.resources);
        // Measured at q = p (the middle q in the default 20/25/30 list).
        let at_p = a
            .measured
            .iter()
            .min_by(|(qa, _), (qb, _)| {
                (qa - r.p()).abs().total_cmp(&(qb - r.p()).abs())
            })
            .map(|(_, m)| m.throughput_sps)
            .unwrap_or(0.0);
        println!(
            "A{:<3} {:>9} {:>9} {:>6} {:>6} {:>5} {:>3.0}% {:>16.0}",
            i + 1,
            a.total_resources.lut,
            a.total_resources.ff,
            a.total_resources.dsp,
            a.total_resources.bram,
            ak.to_string(),
            af * 100.0,
            at_p
        );
    }
    // Headline ratios (paper: 2.17x, same-throughput at 46% resources).
    if let (Some(bb), Some(ba)) = (r.best_baseline(), r.best_design()) {
        let base_thr = bb.measured.throughput_sps;
        let ee_thr = ba
            .measured
            .iter()
            .min_by(|(qa, _), (qb, _)| (qa - r.p()).abs().total_cmp(&(qb - r.p()).abs()))
            .map(|(_, m)| m.throughput_sps)
            .unwrap_or(0.0);
        println!("max ATHEENA / max baseline throughput = {:.2}x", ee_thr / base_thr);
        // Smallest EE design matching the baseline max.
        if let Some(match_d) = r
            .designs
            .iter()
            .filter(|d| {
                d.measured
                    .iter()
                    .min_by(|(qa, _), (qb, _)| (qa - r.p()).abs().total_cmp(&(qb - r.p()).abs()))
                    .map(|(_, m)| m.throughput_sps >= base_thr)
                    .unwrap_or(false)
            })
            .min_by_key(|d| d.total_resources.dsp)
        {
            let (kind, _) = bb.total_resources.limiting(&board.resources);
            let b_lim = bb.total_resources.component(kind) as f64;
            let a_lim = match_d.total_resources.component(kind) as f64;
            println!(
                "ATHEENA matches baseline max throughput with {:.0}% of its limiting resource ({kind})",
                100.0 * a_lim / b_lim
            );
        }
    }
    Ok(())
}

/// Table II — Early-Exit resource overhead as % of the total design.
pub fn table2(ctx: &mut ReportContext) -> anyhow::Result<()> {
    let r = ctx.toolflow("blenet", Board::zc706())?;
    println!("== Table II: Early-Exit overhead (vs network backbone), B-LeNet ==");
    println!(
        "{:>4} {:>9} {:>4} {:>9} {:>4} {:>6} {:>4} {:>6} {:>4}",
        "", "LUT", "%", "FF", "%", "DSP", "%", "BRAM", "%"
    );
    for (i, d) in pick3(&r.designs).iter().enumerate() {
        let ee = d.mapping.ee_overhead_resources();
        let tot = d.total_resources;
        let pct = |a: u64, b: u64| if b == 0 { 0.0 } else { 100.0 * a as f64 / b as f64 };
        println!(
            "A{:<3} {:>9} {:>4.0} {:>9} {:>4.0} {:>6} {:>4.0} {:>6} {:>4.0}",
            i + 1,
            ee.lut,
            pct(ee.lut, tot.lut),
            ee.ff,
            pct(ee.ff, tot.ff),
            ee.dsp,
            pct(ee.dsp, tot.dsp),
            ee.bram,
            pct(ee.bram, tot.bram),
        );
    }
    println!("(paper: overhead dominated by BRAM — conditional buffering + robustness margin)");
    Ok(())
}

/// Table III — comparison against BranchyNet-reported CPU/GPU numbers,
/// plus our measured baseline/ATHEENA accuracy (PJRT numerics) and
/// throughput (simulated board).
pub fn table3(ctx: &mut ReportContext) -> anyhow::Result<()> {
    println!("== Table III: BranchyNet-reported vs this reproduction ==");
    println!(
        "{:>9} {:>9} {:>10} {:>6} {:>16}",
        "platform", "network", "top1(%)", "p(%)", "thr(samples/s)"
    );
    // Quoted from the paper (their Table III, converted from latency).
    for (plat, net, acc, p, thr) in [
        ("CPU", "LeNet", "99.20", "-", "297"),
        ("CPU", "B-LeNet", "99.25", "5.7", "1613"),
        ("GPU", "LeNet", "99.20", "-", "633"),
        ("GPU", "B-LeNet", "99.25", "5.7", "2941"),
    ] {
        println!("{plat:>9} {net:>9} {acc:>10} {p:>6} {thr:>16}  (paper-quoted)");
    }

    // Our measured rows: PJRT accuracy over the synthetic test set +
    // simulated board throughput of the best designs.
    let board = Board::zc706();
    let (base_timing, ee_timing, p, base_thr_sim, ee_thr_sim) = {
        let r = ctx.toolflow("blenet", board.clone())?;
        let bb = r.best_baseline().ok_or_else(|| anyhow::anyhow!("no baseline"))?;
        let ba = r.best_design().ok_or_else(|| anyhow::anyhow!("no design"))?;
        let ee_thr = ba
            .measured
            .iter()
            .min_by(|(qa, _), (qb, _)| (qa - r.p()).abs().total_cmp(&(qb - r.p()).abs()))
            .map(|(_, m)| m.throughput_sps)
            .unwrap_or(0.0);
        (
            DesignTiming::from_baseline_mapping(&bb.mapping),
            ba.timing.clone(),
            r.p(),
            bb.measured.throughput_sps,
            ee_thr,
        )
    };

    let store = ArtifactStore::open(&ctx.artifacts)?;
    let n = if ctx.quick { 256 } else { 1024 };
    let opts = ctx.options(board);
    let ts = ctx.testset("blenet")?;
    let batch = ts.batch_with_q(p, n, 0x7AB3);

    let baseline_exec = store.baseline("blenet")?;
    let bh = BaselineHost {
        exec: &baseline_exec,
        timing: base_timing,
        sim: opts.sim.clone(),
    };
    let base_rep = bh.run(ts, &batch)?;

    let s1 = store.stage1("blenet")?;
    let s2 = store.stage2("blenet")?;
    let eh = BatchHost {
        stage1: &s1,
        stage2: &s2,
        timing: ee_timing,
        sim: opts.sim.clone(),
    };
    let ee_rep = eh.run(ts, &batch)?;

    println!(
        "{:>9} {:>9} {:>10.2} {:>6} {:>16.0}  (ours, simulated board + PJRT accuracy)",
        "Baseline", "LeNet", base_rep.accuracy * 100.0, "-", base_thr_sim
    );
    println!(
        "{:>9} {:>9} {:>10.2} {:>6.1} {:>16.0}  (ours, measured q={:.1}%, flag agreement {:.3})",
        "ATHEENA",
        "B-LeNet",
        ee_rep.accuracy * 100.0,
        p * 100.0,
        ee_thr_sim,
        ee_rep.measured_q * 100.0,
        ee_rep.flag_agreement
    );
    Ok(())
}

/// Table IV — predicted throughput gains for all three networks (B-LeNet
/// on ZC706; Triple-Wins and B-AlexNet on VU440), from the optimizer
/// stage, as in the paper.
pub fn table4(ctx: &mut ReportContext) -> anyhow::Result<()> {
    println!("== Table IV: two-stage ATHEENA vs fpgaConvNet baseline (predicted) ==");
    println!(
        "{:>11} {:>9} {:>9} {:>6} {:>6} {:>16} {:>7}",
        "network", "toolflow", "limit", "lim%", "p(%)", "thr(samples/s)", "gain"
    );
    for (name, board) in [
        ("blenet", Board::zc706()),
        ("triplewins", Board::vu440()),
        ("balexnet", Board::vu440()),
    ] {
        let r = ctx.toolflow(name, board.clone())?;
        let bb = r.best_baseline().ok_or_else(|| anyhow::anyhow!("no baseline"))?;
        let ba = r.best_design().ok_or_else(|| anyhow::anyhow!("no design"))?;
        let (bk, bf) = bb.total_resources.limiting(&board.resources);
        let (ak, af) = ba.total_resources.limiting(&board.resources);
        let base_thr = bb.throughput_predicted;
        let ee_thr = ba.combined.throughput_at_first(r.p());
        println!(
            "{:>11} {:>9} {:>9} {:>5.0}% {:>6} {:>16.0} {:>7}",
            name, "Baseline", bk.to_string(), bf * 100.0, "-", base_thr, "1.00x"
        );
        println!(
            "{:>11} {:>9} {:>9} {:>5.0}% {:>6.0} {:>16.0} {:>6.2}x",
            name,
            "ATHEENA",
            ak.to_string(),
            af * 100.0,
            r.p() * 100.0,
            ee_thr,
            ee_thr / base_thr
        );
    }
    Ok(())
}
