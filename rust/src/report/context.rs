//! Shared, cached state for report generation: toolflow results are
//! computed once per (network, board) and reused across tables/figures.
//! Realized designs additionally persist in the on-disk design cache
//! (`artifacts/designs/`), so re-running a report against a warm store
//! performs zero anneal calls.

use std::collections::HashMap;
use std::path::PathBuf;

use crate::coordinator::pipeline::Realized;
use crate::coordinator::toolflow::{ToolflowOptions, ToolflowResult};
use crate::data::TestSet;
use crate::ir::Network;
use crate::resources::Board;
use crate::runtime::DesignCache;

pub struct ReportContext {
    pub artifacts: PathBuf,
    pub quick: bool,
    results: HashMap<(String, String), ToolflowResult>,
    networks: HashMap<String, Network>,
    testsets: HashMap<String, TestSet>,
}

impl ReportContext {
    pub fn new(artifacts: impl Into<PathBuf>, quick: bool) -> ReportContext {
        ReportContext {
            artifacts: artifacts.into(),
            quick,
            results: HashMap::new(),
            networks: HashMap::new(),
            testsets: HashMap::new(),
        }
    }

    pub fn network(&mut self, name: &str) -> anyhow::Result<Network> {
        if let Some(n) = self.networks.get(name) {
            return Ok(n.clone());
        }
        let path = self.artifacts.join("networks").join(format!("{name}.json"));
        let net = Network::from_file(&path)?;
        self.networks.insert(name.to_string(), net.clone());
        Ok(net)
    }

    pub fn testset(&mut self, name: &str) -> anyhow::Result<&TestSet> {
        if !self.testsets.contains_key(name) {
            let ts = TestSet::load(&self.artifacts, name)?;
            self.testsets.insert(name.to_string(), ts);
        }
        Ok(&self.testsets[name])
    }

    pub fn options(&self, board: Board) -> ToolflowOptions {
        if self.quick {
            ToolflowOptions::quick(board)
        } else {
            ToolflowOptions::new(board)
        }
    }

    /// Toolflow result for (network, board), computed once per context
    /// and loaded from the on-disk design cache when available (the
    /// simulated measurement always re-runs; it is cheap and depends on
    /// the test set). Simulated measurements use test-set-backed hard
    /// flags when the artifacts' data files are present, synthetic
    /// placement otherwise.
    pub fn toolflow(&mut self, name: &str, board: Board) -> anyhow::Result<&ToolflowResult> {
        let key = (name.to_string(), board.name.to_string());
        if !self.results.contains_key(&key) {
            let net = self.network(name)?;
            let opts = self.options(board);
            let cache = DesignCache::open(self.artifacts.join("designs"))?;
            let (realized, _cached) = Realized::load_or_run(&cache, &net, &opts)?;

            let ts = TestSet::load(&self.artifacts, name).ok();
            let seed = 0x51u64;
            let mut flags_fn = ts.map(|ts| {
                move |q: f64, batch: usize| -> Vec<bool> {
                    ts.batch_with_q(q, batch, seed ^ (q * 1e4) as u64).hard
                }
            });
            let r = realized
                .measure(
                    flags_fn
                        .as_mut()
                        .map(|f| f as &mut dyn FnMut(f64, usize) -> Vec<bool>),
                )?
                .into_result();
            self.results.insert(key.clone(), r);
        }
        Ok(&self.results[&key])
    }
}
