//! Report harness: regenerates every table and figure of the paper's
//! evaluation (§IV) from this reproduction's own toolflow + simulator +
//! PJRT numerics. One function per artifact; `all` runs everything.
//!
//! The absolute numbers come from our analytic resource models and the
//! dataflow simulator, not a ZC706 — per DESIGN.md §5 the comparison
//! targets are the *shapes*: who wins, by what factor, where the q
//! deviations land, which resource limits, and where BRAM overhead goes.

pub mod context;
pub mod export;
pub mod figures;
pub mod tables;

pub use context::ReportContext;

/// Run one named report artifact ("fig9a", "table1", ..., "all").
/// "tables" runs Tables I–IV; "pareto" renders the throughput/area
/// frontier table from the persisted design frontier.
pub fn run(name: &str, ctx: &mut ReportContext) -> anyhow::Result<()> {
    match name {
        "fig9a" => figures::fig9a(ctx),
        "fig9b" => figures::fig9b(ctx),
        "fig8" => figures::fig8(ctx),
        "fig7" => figures::fig7(ctx),
        "table1" => tables::table1(ctx),
        "table2" => tables::table2(ctx),
        "table3" => tables::table3(ctx),
        "table4" => tables::table4(ctx),
        "pareto" => tables::pareto(ctx),
        "tables" => {
            for r in ["table1", "table2", "table3", "table4"] {
                run(r, ctx)?;
                println!();
            }
            Ok(())
        }
        "csv" => {
            export::export_fig9(ctx, "blenet", crate::resources::Board::zc706())?;
            export::export_fig7(ctx, "blenet")
        }
        "all" => {
            for r in [
                "fig9a", "fig9b", "fig8", "fig7", "pareto", "table1", "table2", "table3",
                "table4",
            ] {
                run(r, ctx)?;
                println!();
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown report '{other}' \
             (fig9a|fig9b|fig8|fig7|pareto|table1..table4|tables|csv|all)"
        ),
    }
}
