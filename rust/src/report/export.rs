//! CSV export of the figure data series (for external plotting) —
//! written to `artifacts/reports/` by `atheena report ... --csv`.

use std::path::Path;

use super::context::ReportContext;
use crate::resources::Board;

fn write_csv(dir: &Path, name: &str, header: &str, rows: &[String]) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    let mut text = String::from(header);
    text.push('\n');
    for r in rows {
        text.push_str(r);
        text.push('\n');
    }
    std::fs::write(&path, text)?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Export the Fig. 9a/9b series for a network/board to CSV.
pub fn export_fig9(ctx: &mut ReportContext, network: &str, board: Board) -> anyhow::Result<()> {
    let dir = ctx.artifacts.join("reports");
    let r = ctx.toolflow(network, board)?;

    let mut rows = Vec::new();
    for p in &r.baseline_curve.points {
        rows.push(format!(
            "baseline,{:.2},{},{},{},{},{:.1}",
            p.budget_fraction, p.resources.lut, p.resources.ff, p.resources.dsp,
            p.resources.bram, p.throughput
        ));
    }
    let p_hard = r.p();
    for d in &r.designs {
        rows.push(format!(
            "atheena_predicted,{:.2},{},{},{},{},{:.1}",
            d.budget_fraction,
            d.total_resources.lut,
            d.total_resources.ff,
            d.total_resources.dsp,
            d.total_resources.bram,
            d.combined.throughput_at_first(p_hard)
        ));
        for (q, m) in &d.measured {
            rows.push(format!(
                "atheena_measured_q{:.2},{:.2},{},{},{},{},{:.1}",
                q,
                d.budget_fraction,
                d.total_resources.lut,
                d.total_resources.ff,
                d.total_resources.dsp,
                d.total_resources.bram,
                m.throughput_sps
            ));
        }
    }
    write_csv(
        &dir,
        &format!("fig9_{network}.csv"),
        "series,budget_frac,lut,ff,dsp,bram,throughput_sps",
        &rows,
    )
}

/// Export the Fig. 7 depth-sweep series.
pub fn export_fig7(ctx: &mut ReportContext, network: &str) -> anyhow::Result<()> {
    use crate::coordinator::toolflow::synthetic_hard_flags;
    use crate::sim::{simulate_ee, SimMetrics};
    let dir = ctx.artifacts.join("reports");
    let board = Board::zc706();
    let (mut timing, p, sim_cfg, sized) = {
        let opts = ctx.options(board.clone());
        let r = ctx.toolflow(network, board)?;
        let best = r.best_design().ok_or_else(|| anyhow::anyhow!("no design"))?;
        (best.timing.clone(), r.p(), opts.sim, best.cond_buffer_depths[0])
    };
    let flags = synthetic_hard_flags(p, 1024, 0xC5F);
    let mut rows = Vec::new();
    for depth in 0..=(sized * 2) {
        timing.set_cond_buffer_depth(0, depth)?;
        let m = SimMetrics::from_result(&simulate_ee(&timing, &sim_cfg, &flags), sim_cfg.clock_hz);
        rows.push(format!(
            "{depth},{:.1},{},{}",
            m.throughput_sps,
            m.stall_cycles,
            m.deadlock.is_some()
        ));
    }
    write_csv(
        &dir,
        &format!("fig7_{network}.csv"),
        "depth,throughput_sps,stall_cycles,deadlock",
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exports_when_artifacts_present() {
        if !Path::new("artifacts/networks/blenet.json").exists() {
            eprintln!("[skip] artifacts not built");
            return;
        }
        let mut ctx = ReportContext::new("artifacts", true);
        export_fig9(&mut ctx, "blenet", Board::zc706()).unwrap();
        export_fig7(&mut ctx, "blenet").unwrap();
        let fig9 = std::fs::read_to_string("artifacts/reports/fig9_blenet.csv").unwrap();
        assert!(fig9.lines().count() > 5);
        assert!(fig9.starts_with("series,"));
        let fig7 = std::fs::read_to_string("artifacts/reports/fig7_blenet.csv").unwrap();
        assert!(fig7.contains("true"), "deadlock row at depth 0");
    }
}
