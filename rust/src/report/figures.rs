//! Figure regeneration: Fig. 9a (predicted TAP curves), Fig. 9b
//! (simulated-"board" TAP curves at q = 20/25/30%), the Fig. 7
//! buffer-sizing/deadlock ablation, and the Fig. 8 p/q-mismatch
//! envelope (rendered straight from the cached design artifact).

use std::fmt::Write as _;

use super::context::ReportContext;
use crate::coordinator::pipeline::OperatingEnvelope;
use crate::resources::Board;
use crate::sim::{simulate_ee, SimMetrics};

/// Fig. 9a — optimizer-predicted Throughput-Area curves for the B-LeNet
/// baseline and the ATHEENA combined design at p = 25%, with the q = p±5%
/// deviation band (dashed lines in the paper).
pub fn fig9a(ctx: &mut ReportContext) -> anyhow::Result<()> {
    let board = Board::zc706();
    let r = ctx.toolflow("blenet", board.clone())?;
    println!("== Fig. 9a: predicted TAP, B-LeNet on ZC706, p = {:.0}% ==", r.p() * 100.0);
    println!("-- baseline (fpgaConvNet) --");
    println!("{:>8} {:>10} {:>8} {:>16} {:>10}", "budget%", "LUT", "DSP", "thr(samples/s)", "limit");
    for p in &r.baseline_curve.points {
        let (kind, frac) = p.resources.limiting(&board.resources);
        println!(
            "{:>8.0} {:>10} {:>8} {:>16.0} {:>6} {:>3.0}%",
            p.budget_fraction * 100.0,
            p.resources.lut,
            p.resources.dsp,
            p.throughput,
            kind.to_string(),
            frac * 100.0
        );
    }
    println!("-- ATHEENA combined (Eq. 1), q deviations --");
    println!(
        "{:>8} {:>8} {:>16} {:>16} {:>16}",
        "budget%", "DSP", "thr@q=p-5%", "thr@q=p", "thr@q=p+5%"
    );
    let p = r.p();
    for d in &r.designs {
        println!(
            "{:>8.0} {:>8} {:>16.0} {:>16.0} {:>16.0}",
            d.budget_fraction * 100.0,
            d.total_resources.dsp,
            d.combined.throughput_at_first((p - 0.05).max(0.01)),
            d.combined.throughput_at_first(p),
            d.combined.throughput_at_first(p + 0.05),
        );
    }
    Ok(())
}

/// Fig. 9b — "board" (simulator) Throughput-Area results with test
/// batches at q = 30/25/20% hard samples.
pub fn fig9b(ctx: &mut ReportContext) -> anyhow::Result<()> {
    let board = Board::zc706();
    let r = ctx.toolflow("blenet", board.clone())?;
    println!("== Fig. 9b: measured (simulated board) TAP, B-LeNet on ZC706 ==");
    println!("-- baseline --");
    println!("{:>8} {:>8} {:>16}", "budget%", "DSP", "thr(samples/s)");
    for b in &r.baseline_designs {
        println!(
            "{:>8.0} {:>8} {:>16.0}",
            b.budget_fraction * 100.0,
            b.total_resources.dsp,
            b.measured.throughput_sps
        );
    }
    println!("-- ATHEENA (batch 1024, randomly-placed hard samples) --");
    print!("{:>8} {:>8} {:>6}", "budget%", "DSP", "limit");
    let qs: Vec<f64> = r.designs[0].measured.iter().map(|(q, _)| *q).collect();
    for q in &qs {
        print!(" {:>14}", format!("thr@q={:.0}%", q * 100.0));
    }
    println!();
    for d in &r.designs {
        let (kind, _) = d.total_resources.limiting(&board.resources);
        print!(
            "{:>8.0} {:>8} {:>6}",
            d.budget_fraction * 100.0,
            d.total_resources.dsp,
            kind.to_string()
        );
        for (_, m) in &d.measured {
            print!(" {:>14.0}", m.throughput_sps);
        }
        println!();
    }
    Ok(())
}

/// Fig. 8 — the p/q-mismatch operating envelope of every chosen design:
/// throughput over a q-grid around the design p, stall onset, and the
/// safe operating region. The table is read from the envelope persisted
/// inside the design artifact, so a warm cache renders it with zero
/// anneal calls and zero fresh simulation sweeps.
pub fn fig8(ctx: &mut ReportContext) -> anyhow::Result<()> {
    let r = ctx.toolflow("blenet", Board::zc706())?;
    println!(
        "== Fig. 8: operating envelope (p/q mismatch), B-LeNet on ZC706, p = {:.0}% ==",
        r.p() * 100.0
    );
    for d in &r.designs {
        print!(
            "{}",
            render_fig8_design(d.budget_fraction, d.total_resources.dsp, &d.envelope)
        );
    }
    Ok(())
}

/// Render one design's Fig. 8 envelope block. Pure function of the
/// persisted envelope — golden-tested byte-for-byte in
/// `tests/integration.rs` (the `fig8` CLI output is these blocks under
/// one header).
pub fn render_fig8_design(budget_fraction: f64, dsp: u64, e: &OperatingEnvelope) -> String {
    let mut s = String::new();
    let at_p = e.throughput_at_design();
    let _ = writeln!(
        s,
        "-- budget {:.0}%, {} DSP, safe up to q = {:.0}%{} --",
        budget_fraction * 100.0,
        dsp,
        e.safe_q_max() * 100.0,
        match e.stall_onset_q() {
            Some(q) => format!(", stalls from q = {:.0}%", q * 100.0),
            None => ", stall-free across the grid".to_string(),
        }
    );
    let _ = writeln!(
        s,
        "{:>8} {:>8} {:>16} {:>10} {:>12} {:>10}",
        "q%", "q/p", "thr(samples/s)", "vs design", "stallcycles", "status"
    );
    for pt in &e.points {
        let _ = writeln!(
            s,
            "{:>8.1} {:>8.2} {:>16.0} {:>9.0}% {:>12} {:>10}",
            pt.q * 100.0,
            pt.q / e.design_p,
            pt.throughput_sps,
            100.0 * pt.throughput_sps / at_p.max(1e-9),
            pt.stall_cycles,
            if pt.deadlock { "DEADLOCK" } else { "ok" }
        );
    }
    s
}

/// Fig. 7 ablation — Conditional Buffer depth sweep: throughput and stall
/// cycles vs depth, deadlock at depth 0, plateau at the sized minimum.
pub fn fig7(ctx: &mut ReportContext) -> anyhow::Result<()> {
    let board = Board::zc706();
    let q = {
        let r = ctx.toolflow("blenet", board.clone())?;
        r.p()
    };
    let r = ctx.toolflow("blenet", board)?;
    let best = r
        .best_design()
        .ok_or_else(|| anyhow::anyhow!("no design"))?;
    let sized = best.cond_buffer_depths[0];
    println!("== Fig. 7 ablation: Conditional Buffer sizing (B-LeNet best design) ==");
    println!("sized depth (min + margin) = {sized} samples");
    println!(
        "{:>7} {:>16} {:>12} {:>10}",
        "depth", "thr(samples/s)", "stallcycles", "status"
    );
    let mut timing = best.timing.clone();
    let flags =
        crate::coordinator::toolflow::synthetic_hard_flags(q, 1024, 0xF16_7);
    for depth in [0usize, 1, 2, 3, 4, 6, 8, 12, 16, sized, sized * 2] {
        timing.set_cond_buffer_depth(0, depth)?;
        let sim = simulate_ee(&timing, &ctx.options(Board::zc706()).sim, &flags);
        let m = SimMetrics::from_result(&sim, 125e6);
        println!(
            "{:>7} {:>16.0} {:>12} {:>10}",
            depth,
            m.throughput_sps,
            m.stall_cycles,
            if m.deadlock.is_some() { "DEADLOCK" } else { "ok" }
        );
    }
    Ok(())
}
