//! # ATHEENA — A Toolflow for Hardware Early-Exit Network Automation
//!
//! Reproduction of Biggs, Bouganis & Constantinides (2023). The library
//! implements the paper's full toolflow as a **typed, staged pipeline**
//! (see `coordinator::pipeline`):
//!
//! ```text
//! Toolflow::new(net, opts) -> Lowered -> .sweep() -> Curves
//!     -> .combine() -> Combined -> .realize() -> Realized
//!     -> .measure(flags) -> Measured
//! ```
//!
//! The stage model is **N-exit throughout** (§III-A's "trivial to
//! extend … to multi-stage networks", taken literally): a network is a
//! chain of backbone *sections* separated by early exits, and the
//! number of exits is data — `ir::StageId::{Backbone(i),
//! ExitBranch(i), Egress}`, one Conditional Buffer per exit, one TAP
//! curve per section. The paper's two-stage configuration is the
//! one-exit special case and is bit-identical to the dedicated
//! two-stage code it replaced.
//!
//! * **`Lowered`** — network IR parsed and validated, then lowered into
//!   the Early-Exit CDFG (Fig. 3, N-exit form) and the single-stage
//!   baseline graph, with the design-time reach-probability vector
//!   resolved.
//! * **`Curves`** — per-section Throughput-Area Pareto (TAP) curves
//!   from fpgaConvNet-style simulated-annealing DSE over folding
//!   assignments. The budget sweeps run on scoped threads, one seeded
//!   anneal per (section, fraction), bit-identical to the sequential
//!   path.
//! * **`Combined`** — the multi-stage Eq. 1 (`tap::combine_multi`):
//!   the resource split maximizing `min_i f_i(x_i) / r_i` per budget,
//!   with the annealed foldings merged into one full-CDFG mapping. At
//!   two stages this selects exactly what the pairwise `tap::combine`
//!   would.
//! * **`Realized`** — per-exit Conditional Buffer sizing (Fig. 7) plus
//!   margin, budget re-check, HLS design-manifest generation and
//!   stitch checks, pipeline-section timing extraction. This is the
//!   *cacheable* artifact: it serializes into the
//!   `runtime::DesignCache` (`artifacts/designs/`) under a
//!   schema-versioned fingerprint, so `infer`, `serve`, and `report`
//!   reuse a previously realized design with zero anneal calls and
//!   stale-schema artifacts are evicted, never mis-parsed.
//! * **`Measured`** — the event-driven streaming-dataflow simulator
//!   (the board substitute) measures every design at the requested q
//!   ladder, reporting per-exit completion rates alongside throughput.
//!   Each design also carries its persisted **operating envelope**
//!   (the Fig. 8-style p/q-mismatch sweep), cached with the artifact.
//!
//! The legacy monolithic entry point `coordinator::toolflow::run_toolflow`
//! survives as a thin wrapper over this chain.
//!
//! Beyond the design-time flow, the **reach vector is a runtime
//! signal**: `ee::OperatingPoint` bundles per-exit thresholds with the
//! reach they induce, `ee::decision::ThresholdPolicy` decides exits at
//! that point (`Fixed` is bit-identical to the scalar-`c_thr` path;
//! `Controller` retunes thresholds from observed confidences via the
//! `threshold_for_p` calibration), and `ee::ReachEstimator` measures
//! realized reach streamingly. `sim::drift` closes the loop in
//! simulation — step/ramp/periodic difficulty drifts with per-window
//! throughput and rate reports — and `coordinator::server` closes it in
//! deployment (`ServePolicy`, realized exit-rate + backpressure
//! metrics). See DESIGN.md §6.
//!
//! Around the pipeline sit the supporting layers: network IR parsing
//! (`ir`), folding + resource models (`sdf`, `resources`), the DSE
//! (`dse`), TAP algebra (`tap`), the N-exit simulator (`sim`), the HLS
//! manifest generator (`hls`), a PJRT runtime executing the
//! JAX/Pallas-AOT network numerics (`runtime`), and the batched
//! inference / serving coordinator (`coordinator::batch` /
//! `coordinator::server` — the latter a chain of per-section stage
//! workers routing hard samples downstream, sharing one dynamic
//! batcher implementation with the batch host).
//!
//! The search itself is **objective-aware** (DESIGN.md §8):
//! `dse::Objective` selects between maximizing throughput under a
//! budget, minimizing the scalar area norm
//! (`resources::ResourceVec::utilization`) at a throughput target, and
//! tracing the whole throughput/area Pareto frontier (`dse::pareto`,
//! budget-scaling sweeps on the deterministic executor). The realized
//! artifact persists a `coordinator::DesignFrontier` (baseline + EE
//! fronts, schema v5), so `atheena pareto` reproduces the paper's
//! "same throughput at 46% of the resources" comparison from a warm
//! cache with zero anneal calls, and `atheena pack` greedily
//! co-resides multiple realized designs on one board budget — the
//! first multi-tenant workload.
//!
//! The cold search path is driven through a crate-wide **performance
//! layer** (DESIGN.md §7): `util::exec` is a deterministic scoped-
//! thread executor (results in task order, bit-identical to sequential,
//! nested calls collapse inline) running the TAP sweeps, anneal
//! restarts, operating-envelope q-grid, drift-window statistics, and
//! profiler split statistics; `sim::SimScratch` makes steady-state
//! simulation allocation-free; and the annealer's `EvalCache` keeps its
//! max-II incrementally (count-of-max with lazy argmax repair). Every
//! optimization is property-tested bit-identical to its reference path,
//! and `bench_hotpath` tracks the wins in `BENCH_{sim,dse,e2e}.json`.
//!
//! The simulator itself is **compiled** (DESIGN.md §10): a one-time
//! lowering pass (`sim::lower`) flattens a `DesignTiming` +
//! `SimConfig` into a branch-minimal flat op table executed by
//! `sim::CompiledDesign` over structure-of-arrays sample state in a
//! reusable `sim::CompiledScratch`. The interpreted `simulate_multi`
//! stays untouched as the bit-identical reference oracle
//! (property-tested in `tests/compiled_props.rs`, fault RNG stream
//! included); `sim::SimBackend` selects the core per run — the compiled
//! path is the default for the envelope q-grid, `Realized::measure`,
//! and the untraced closed-loop drift windows, and `--backend
//! interpreted` switches any CLI run back to the oracle. Traced runs
//! always interpret (the compiled kernel carries no sink hooks). A
//! `DesignTiming::generation` counter invalidates compiled tables
//! lowered from a since-mutated timing. `atheena trace diff A.json
//! B.json` aligns two pinned-seed trace streams per track and reports
//! the first diverging event — the debugging instrument for exactly
//! this kind of dual-core work.
//!
//! The DSE is **incremental** (DESIGN.md §11): the budget-scaling
//! ladder chains warm starts — rungs sweep descending, each seeded
//! from the adjacent larger budget's accepted mapping clipped into the
//! smaller budget (`dse::WarmStart`, `dse::anneal_seeded`,
//! `Problem::clip_into_budget`), with the cold
//! `sweep_frontier_sequential` kept as the reference oracle and a
//! property gate pinning that the warm frontier is never dominated by
//! the cold one at any budget point. The Eq. 1 multi-stage search
//! prunes with precomputed per-suffix admissible bounds
//! (`tap::SuffixBounds`, reusable across a whole budget ladder) while
//! staying bit-identical to the unpruned `tap::combine_multi_reference`.
//! And a content-addressed lowering arena (`sim::CompiledArena` /
//! `sim::SharedArena`, keyed on timing content + DMA width, generation
//! drift re-stamped) memoizes compiled-simulator lowerings across
//! `Realized::measure`, frontier realization, and envelope sweeps.
//!
//! The DSE is also **certified** (DESIGN.md §13): `dse::exact` is a
//! deterministic branch-and-bound over the per-node folding ladder —
//! dominance-filtered candidates, admissible II/resource bounds,
//! property-tested **bit-identical** to its unpruned
//! `dse::exact_exhaustive` reference on small problems — exact under
//! both objective arms, with an explicit `dse::ExactConfig` size
//! budget (`TooLarge`, never unbounded search). `dse::exact_seeded`
//! certifies a recorded design from a virtual incumbent (gap 0 is
//! proved, not sampled), `dse::certify` wraps an anneal into a
//! `dse::CertifiedGap`, and `Realized::certify_frontier` stamps a
//! per-point optimality gap into the schema-v5 frontier with zero
//! anneal calls — surfaced as `atheena pareto --certify [--max-gap]`
//! ("%cert-opt" column) and gated in CI at a 5% max gap on the
//! pinned-seed testnet. `tap::combine_multi_min_area` adds the dual
//! Eq. 1 combination (min total resources at a throughput target,
//! bit-identical to its brute-force reference) and polishes
//! `min_area_design`'s refinement.
//!
//! Observability is per-sample, not just aggregate (DESIGN.md §9): the
//! `trace` subsystem captures structured events (`SampleAdmitted`,
//! `SectionEnter/Exit`, `ExitTaken`, `BufferStalled/Drained`,
//! `ThresholdRetuned`, `WindowStats`) from the simulator
//! (`sim::simulate_multi_traced`), the closed-loop drift harness
//! (`sim::drift::simulate_closed_loop_traced`), and the serving
//! coordinator, behind the zero-cost `trace::TraceSink` contract — the
//! default `trace::NullSink` leaves the hot paths bit-identical and
//! allocation-free. A bounded `trace::Recorder` ring feeds the
//! Chrome-trace/Perfetto exporter (`atheena trace` writes `trace.json`
//! for `ui.perfetto.dev`) and the `trace::TraceSummary` aggregation
//! (per-exit latency distributions, per-buffer stall totals,
//! controller reconvergence time).
//!
//! Serving is **degradation-aware** (DESIGN.md §12): a seeded
//! `coordinator::ServeFaultPlan` schedules deterministic worker
//! crashes, stalls, decision jitter, and input bursts against either
//! the real threaded server or the closed-loop harness
//! (`sim::simulate_closed_loop_chaos`) — one fault schedule, both
//! substrates. Stage workers run under a supervisor
//! (`catch_unwind` + bounded restarts with exponential backoff) that
//! preserves the in-flight sample across respawns and, on budget
//! exhaustion, drains the stage gracefully into a structured
//! `coordinator::ShutdownReport`. Admission control
//! (`coordinator::AdmissionConfig`) adds per-sample deadlines and
//! high/low inflight watermarks with a `coordinator::ShedPolicy` —
//! reject, force the next exit (`ThresholdPolicy::decide_forced`), or
//! spill to a dedicated baseline worker — under the conservation law
//! `admitted == served + spilled + shed + errors + failed`, checked by
//! `ServerStats::conservation` and property-tested in
//! `tests/server_props.rs` with the deterministic
//! `coordinator::SyntheticEngineFactory`.
//!
//! See `DESIGN.md` for the architecture, the pipeline-stage contracts,
//! and the substitution rationale, and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod coordinator;
pub mod data;
pub mod dse;
pub mod ee;
pub mod hls;
pub mod ir;
pub mod report;
pub mod resources;
pub mod runtime;
pub mod sdf;
pub mod sim;
pub mod tap;
pub mod trace;
pub mod util;
