//! # ATHEENA — A Toolflow for Hardware Early-Exit Network Automation
//!
//! Reproduction of Biggs, Bouganis & Constantinides (2023). The library
//! implements the paper's full toolflow as a **typed, staged pipeline**
//! (see `coordinator::pipeline`):
//!
//! ```text
//! Toolflow::new(net, opts) -> Lowered -> .sweep() -> Curves
//!     -> .combine() -> Combined -> .realize() -> Realized
//!     -> .measure(flags) -> Measured
//! ```
//!
//! * **`Lowered`** — network IR parsed and validated, then lowered into
//!   the Early-Exit CDFG (Fig. 3) and the single-stage baseline graph.
//! * **`Curves`** — per-stage Throughput-Area Pareto (TAP) curves from
//!   fpgaConvNet-style simulated-annealing DSE over folding assignments.
//!   The budget sweeps run on scoped threads, one seeded anneal per
//!   (stage, fraction), bit-identical to the sequential path.
//! * **`Combined`** — Eq. 1's TAP combination: the optimal
//!   (stage-1, stage-2) resource split per budget, with the annealed
//!   foldings merged into one full-CDFG mapping.
//! * **`Realized`** — Conditional Buffer sizing (Fig. 7) plus margin,
//!   budget re-check, HLS design-manifest generation and stitch checks,
//!   pipeline-section timing extraction. This is the *cacheable*
//!   artifact: it serializes into the `runtime::DesignCache`
//!   (`artifacts/designs/`), so `infer`, `serve`, and `report` reuse a
//!   previously realized design with zero anneal calls.
//! * **`Measured`** — the event-driven streaming-dataflow simulator (the
//!   board substitute) measures every design at the requested q ladder.
//!
//! The legacy monolithic entry point `coordinator::toolflow::run_toolflow`
//! survives as a thin wrapper over this chain.
//!
//! Around the pipeline sit the supporting layers: network IR parsing
//! (`ir`), folding + resource models (`sdf`, `resources`), the DSE
//! (`dse`), TAP algebra (`tap`), the simulator (`sim`), the HLS manifest
//! generator (`hls`), a PJRT runtime executing the JAX/Pallas-AOT network
//! numerics (`runtime`), and the batched inference / serving coordinator
//! (`coordinator::batch` / `coordinator::server`).
//!
//! See `DESIGN.md` for the architecture, the pipeline-stage contracts,
//! and the substitution rationale, and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod coordinator;
pub mod data;
pub mod dse;
pub mod ee;
pub mod hls;
pub mod ir;
pub mod report;
pub mod resources;
pub mod runtime;
pub mod sdf;
pub mod sim;
pub mod tap;
pub mod util;
