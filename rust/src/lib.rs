//! # ATHEENA — A Toolflow for Hardware Early-Exit Network Automation
//!
//! Reproduction of Biggs, Bouganis & Constantinides (2023). The library
//! implements the full toolflow: network IR parsing, CDFG lowering with
//! the Early-Exit hardware layers, fpgaConvNet-style folding + resource
//! models, simulated-annealing DSE, TAP combination (Eq. 1), Conditional
//! Buffer sizing (Fig. 7), an event-driven streaming-dataflow simulator
//! (the board substitute), an HLS design-manifest generator, a PJRT
//! runtime executing the JAX/Pallas-AOT network numerics, and the batched
//! inference / serving coordinator.
//!
//! See `DESIGN.md` for the architecture and substitution rationale and
//! `EXPERIMENTS.md` for the paper-vs-measured record.

pub mod coordinator;
pub mod data;
pub mod dse;
pub mod ee;
pub mod hls;
pub mod ir;
pub mod report;
pub mod resources;
pub mod runtime;
pub mod sdf;
pub mod sim;
pub mod tap;
pub mod util;
