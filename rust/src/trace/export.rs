//! Chrome-trace / Perfetto JSON export and validation.
//!
//! Converts a flat [`TraceEvent`] stream into the Chrome trace-event
//! format (the JSON flavour `ui.perfetto.dev` and `chrome://tracing`
//! both load): `{"traceEvents": [...], "displayTimeUnit": "ms"}` with
//! timestamps in microseconds.
//!
//! Track layout (pid = process row, tid = thread row):
//! - pid 0 `pipeline`: one group of lanes per backbone section. Section
//!   occupancy is pipelined (a section holds `latency/II` samples at
//!   once), so spans are `X` (complete) events placed on the lowest
//!   free lane of their section — `tid = section * LANE_STRIDE + lane`.
//!   Flow events (`s`/`t`/`f`, id = sample) link one sample's spans
//!   across sections.
//! - pid 1 `buffers`: per-buffer stall spans (`B`/`E`; the producing
//!   section blocks while stalled, so these never overlap) on
//!   `tid = buffer`, plus an occupancy counter track per buffer
//!   (sweep-line over `BufferDrained` residency intervals, or direct
//!   `BufferOccupancy` samples from the server).
//! - pid 2 `samples`: whole-pipeline residency (`SampleAdmitted` →
//!   `SampleRetired`) as lane-packed `X` spans.
//! - pid 3 `exits`: one instant (`i`) per sample on `tid = stage`.
//! - pid 4 `control`: closed-loop window spans, retune instants, and
//!   `throughput_sps` / per-threshold counter tracks, plus (tid 1,
//!   only when present) a `degradation` lane of shed / forced-exit /
//!   worker-stall / worker-restart instants.
//!
//! The export is fully deterministic (stable sort, `BTreeMap` series)
//! so pinned-seed traces golden-test byte-for-byte.

use std::collections::BTreeMap;

use super::event::TraceEvent;
use crate::util::json::{self, Json};

/// pid of the per-section pipeline lanes.
pub const PID_PIPELINE: u32 = 0;
/// pid of the Conditional Buffer stall/occupancy tracks.
pub const PID_BUFFERS: u32 = 1;
/// pid of the whole-pipeline sample-residency lanes.
pub const PID_SAMPLES: u32 = 2;
/// pid of the per-exit instant tracks.
pub const PID_EXITS: u32 = 3;
/// pid of the closed-loop control tracks.
pub const PID_CONTROL: u32 = 4;

/// tid stride between section lane groups on the pipeline process.
/// A section never holds more than `latency` samples at once, so 4096
/// lanes per section is far beyond any design the simulator accepts.
pub const LANE_STRIDE: u32 = 4096;

/// Convert producer ticks to trace microseconds, rounded to
/// nanosecond precision (keeps the JSON compact and deterministic;
/// rounding is monotone, so track ordering survives the conversion).
fn us(ticks: u64, clock_hz: f64) -> f64 {
    (ticks as f64 * 1e6 / clock_hz * 1000.0).round() / 1000.0
}

/// Greedy deterministic lane packing. `spans` must be sorted by
/// `(start, end)`; returns one lane index per span such that spans
/// sharing a lane never overlap (a lane is reusable at `end`, i.e.
/// `[start, end)` residency).
fn assign_lanes(spans: &[(u64, u64)]) -> Vec<u32> {
    let mut lane_free: Vec<u64> = Vec::new();
    let mut lanes = Vec::with_capacity(spans.len());
    for &(start, end) in spans {
        let lane = match lane_free.iter().position(|&free| free <= start) {
            Some(l) => l,
            None => {
                lane_free.push(0);
                lane_free.len() - 1
            }
        };
        lane_free[lane] = end.max(start + 1);
        lanes.push(lane as u32);
    }
    lanes
}

fn meta(pid: u32, tid: Option<u32>, which: &str, name: &str) -> Json {
    let mut pairs = vec![
        ("ph", Json::str("M")),
        ("name", Json::str(which)),
        ("pid", Json::num(pid as f64)),
        ("ts", Json::num(0.0)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ];
    if let Some(tid) = tid {
        pairs.push(("tid", Json::num(tid as f64)));
    }
    Json::obj(pairs)
}

fn counter(pid: u32, name: &str, ts: f64, series: Vec<(&str, f64)>) -> Json {
    Json::obj(vec![
        ("ph", Json::str("C")),
        ("name", Json::str(name)),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(0.0)),
        ("ts", Json::num(ts)),
        (
            "args",
            Json::obj(series.into_iter().map(|(k, v)| (k, Json::num(v))).collect()),
        ),
    ])
}

/// Build the Chrome-trace JSON document for an event stream.
/// `clock_hz` converts producer ticks to microseconds (the simulator
/// passes the design clock; the server records ticks in microseconds
/// already and passes `1e6`).
pub fn export_chrome_trace(events: &[TraceEvent], clock_hz: f64) -> Json {
    // ---- bucket the flat stream ---------------------------------------
    // (sample, section) -> enter tick, then matched into spans.
    let mut open_sections: BTreeMap<(u64, u32), u64> = BTreeMap::new();
    // section -> [(start, end, sample)]
    let mut section_spans: BTreeMap<u32, Vec<(u64, u64, u64)>> = BTreeMap::new();
    let mut admits: BTreeMap<u64, u64> = BTreeMap::new();
    let mut lifetimes: Vec<(u64, u64, u64)> = Vec::new(); // (admit, retire, sample)
    let mut exits: Vec<(u64, u32, u64)> = Vec::new(); // (sample, stage, t)
    let mut stalls: BTreeMap<u32, Vec<(u64, u64, u64)>> = BTreeMap::new(); // buf -> (t, cycles, sample)
    let mut drains: BTreeMap<u32, Vec<(u64, u64, u64, bool)>> = BTreeMap::new();
    let mut occupancy: BTreeMap<u32, Vec<(u64, u32)>> = BTreeMap::new();
    let mut retunes: Vec<(u32, u64, Vec<f64>, u64)> = Vec::new();
    // (window, start_sample, len, t_start, t_end, throughput_sps, reach)
    let mut windows = Vec::new();
    // Degradation instants: (t, name, arg-name, arg-value).
    let mut degradation: Vec<(u64, String, &'static str, f64)> = Vec::new();

    for ev in events {
        match ev {
            TraceEvent::SampleAdmitted { sample, t } => {
                admits.insert(*sample, *t);
            }
            TraceEvent::SectionEnter { sample, section, t } => {
                open_sections.insert((*sample, *section), *t);
            }
            TraceEvent::SectionExit { sample, section, t } => {
                // An exit without a recorded enter (ring-buffer wrap)
                // becomes a zero-length span at the exit tick.
                let enter = open_sections
                    .remove(&(*sample, *section))
                    .unwrap_or(*t);
                section_spans
                    .entry(*section)
                    .or_default()
                    .push((enter, *t, *sample));
            }
            TraceEvent::ExitTaken { sample, stage, t } => {
                exits.push((*sample, *stage, *t));
            }
            TraceEvent::SampleRetired { sample, t } => {
                let admit = admits.get(sample).copied().unwrap_or(*t);
                lifetimes.push((admit, *t, *sample));
            }
            TraceEvent::BufferStalled {
                buffer,
                sample,
                t,
                cycles,
            } => {
                if *cycles > 0 {
                    stalls.entry(*buffer).or_default().push((*t, *cycles, *sample));
                }
            }
            TraceEvent::BufferDrained {
                buffer,
                sample,
                enter,
                leave,
                dropped,
            } => {
                drains
                    .entry(*buffer)
                    .or_default()
                    .push((*enter, *leave, *sample, *dropped));
            }
            TraceEvent::BufferOccupancy {
                buffer,
                t,
                occupancy: occ,
            } => {
                occupancy.entry(*buffer).or_default().push((*t, *occ));
            }
            TraceEvent::ThresholdRetuned {
                window,
                t,
                thresholds,
                retunes: n,
            } => {
                retunes.push((*window, *t, thresholds.clone(), *n));
            }
            TraceEvent::WindowStats {
                window,
                start_sample,
                len,
                t_start,
                t_end,
                throughput_sps,
                reach,
            } => {
                windows.push((
                    *window,
                    *start_sample,
                    *len,
                    *t_start,
                    *t_end,
                    *throughput_sps,
                    reach.clone(),
                ));
            }
            TraceEvent::SampleShed { sample, t } => {
                degradation.push((*t, "shed".to_string(), "sample", *sample as f64));
            }
            TraceEvent::DeadlineForcedExit { sample, stage, t } => {
                degradation.push((
                    *t,
                    format!("forced-exit{stage}"),
                    "sample",
                    *sample as f64,
                ));
            }
            TraceEvent::WorkerStalled { stage, t, millis } => {
                degradation.push((
                    *t,
                    format!("stall stage{stage}"),
                    "millis",
                    *millis as f64,
                ));
            }
            TraceEvent::WorkerRestarted { stage, t, restarts } => {
                degradation.push((
                    *t,
                    format!("restart stage{stage}"),
                    "restarts",
                    *restarts as f64,
                ));
            }
        }
    }

    // Synthesise occupancy counters from residency intervals when the
    // producer emitted drains (simulator) but no direct samples.
    for (buf, intervals) in &drains {
        if occupancy.contains_key(buf) {
            continue;
        }
        // Sweep-line: at equal ticks apply leaves (-1) before enters
        // (+1) so a same-cycle swap doesn't over-count the peak.
        let mut edges: Vec<(u64, i32)> = Vec::with_capacity(intervals.len() * 2);
        for &(enter, leave, _, _) in intervals {
            edges.push((enter, 1));
            edges.push((leave, -1));
        }
        edges.sort_by_key(|&(t, delta)| (t, delta));
        let mut level = 0i32;
        let mut series: Vec<(u64, u32)> = Vec::new();
        let mut i = 0;
        while i < edges.len() {
            let t = edges[i].0;
            while i < edges.len() && edges[i].0 == t {
                level += edges[i].1;
                i += 1;
            }
            series.push((t, level.max(0) as u32));
        }
        occupancy.insert(*buf, series);
    }

    // ---- emit ---------------------------------------------------------
    let mut out: Vec<Json> = Vec::new();
    out.push(meta(PID_PIPELINE, None, "process_name", "pipeline"));
    out.push(meta(PID_BUFFERS, None, "process_name", "buffers"));
    out.push(meta(PID_SAMPLES, None, "process_name", "samples"));
    out.push(meta(PID_EXITS, None, "process_name", "exits"));
    out.push(meta(PID_CONTROL, None, "process_name", "control"));

    // ts-sortable body events, built unsorted then stably sorted.
    let mut body: Vec<(f64, Json)> = Vec::new();

    // Section lanes + flows.
    // sample -> ordered (section, start, tid) for flow linkage.
    let mut sample_hops: BTreeMap<u64, Vec<(u32, u64, u32)>> = BTreeMap::new();
    for (section, spans) in &mut section_spans {
        spans.sort();
        let lanes = assign_lanes(
            &spans.iter().map(|&(s, e, _)| (s, e)).collect::<Vec<_>>(),
        );
        let max_lane = lanes.iter().copied().max().unwrap_or(0);
        for lane in 0..=max_lane {
            out.push(meta(
                PID_PIPELINE,
                Some(section * LANE_STRIDE + lane),
                "thread_name",
                &format!("sec{section}/lane{lane}"),
            ));
        }
        for (&(start, end, sample), &lane) in spans.iter().zip(&lanes) {
            let tid = section * LANE_STRIDE + lane;
            let ts = us(start, clock_hz);
            body.push((
                ts,
                Json::obj(vec![
                    ("ph", Json::str("X")),
                    ("name", Json::str(format!("s{sample}"))),
                    ("cat", Json::str("section")),
                    ("pid", Json::num(PID_PIPELINE as f64)),
                    ("tid", Json::num(tid as f64)),
                    ("ts", Json::num(ts)),
                    ("dur", Json::num(us(end, clock_hz) - ts)),
                    (
                        "args",
                        Json::obj(vec![
                            ("sample", Json::num(sample as f64)),
                            ("section", Json::num(*section as f64)),
                        ]),
                    ),
                ]),
            ));
            sample_hops
                .entry(sample)
                .or_default()
                .push((*section, start, tid));
        }
    }
    for (sample, hops) in &mut sample_hops {
        if hops.len() < 2 {
            continue;
        }
        hops.sort();
        let last = hops.len() - 1;
        for (i, &(_, start, tid)) in hops.iter().enumerate() {
            let ph = if i == 0 {
                "s"
            } else if i == last {
                "f"
            } else {
                "t"
            };
            let ts = us(start, clock_hz);
            let mut pairs = vec![
                ("ph", Json::str(ph)),
                ("name", Json::str("sample")),
                ("cat", Json::str("flow")),
                ("id", Json::num(*sample as f64)),
                ("pid", Json::num(PID_PIPELINE as f64)),
                ("tid", Json::num(tid as f64)),
                ("ts", Json::num(ts)),
            ];
            if ph == "f" {
                // Bind the flow end to the enclosing slice's start.
                pairs.push(("bp", Json::str("e")));
            }
            body.push((ts, Json::obj(pairs)));
        }
    }

    // Buffer stalls and occupancy.
    for (buf, list) in &mut stalls {
        out.push(meta(
            PID_BUFFERS,
            Some(*buf),
            "thread_name",
            &format!("buf{buf} stalls"),
        ));
        list.sort();
        for &(t, cycles, sample) in list.iter() {
            let ts = us(t, clock_hz);
            let te = us(t + cycles, clock_hz);
            body.push((
                ts,
                Json::obj(vec![
                    ("ph", Json::str("B")),
                    ("name", Json::str("stall")),
                    ("cat", Json::str("buffer")),
                    ("pid", Json::num(PID_BUFFERS as f64)),
                    ("tid", Json::num(*buf as f64)),
                    ("ts", Json::num(ts)),
                    (
                        "args",
                        Json::obj(vec![
                            ("sample", Json::num(sample as f64)),
                            ("cycles", Json::num(cycles as f64)),
                        ]),
                    ),
                ]),
            ));
            body.push((
                te,
                Json::obj(vec![
                    ("ph", Json::str("E")),
                    ("name", Json::str("stall")),
                    ("cat", Json::str("buffer")),
                    ("pid", Json::num(PID_BUFFERS as f64)),
                    ("tid", Json::num(*buf as f64)),
                    ("ts", Json::num(te)),
                ]),
            ));
        }
    }
    for (buf, series) in &occupancy {
        for &(t, occ) in series {
            body.push((
                us(t, clock_hz),
                counter(
                    PID_BUFFERS,
                    &format!("buf{buf} occupancy"),
                    us(t, clock_hz),
                    vec![("occupancy", occ as f64)],
                ),
            ));
        }
    }

    // Whole-pipeline sample residency lanes.
    if !lifetimes.is_empty() {
        lifetimes.sort();
        let lanes = assign_lanes(
            &lifetimes.iter().map(|&(s, e, _)| (s, e)).collect::<Vec<_>>(),
        );
        let max_lane = lanes.iter().copied().max().unwrap_or(0);
        for lane in 0..=max_lane {
            out.push(meta(
                PID_SAMPLES,
                Some(lane),
                "thread_name",
                &format!("lane{lane}"),
            ));
        }
        for (&(start, end, sample), &lane) in lifetimes.iter().zip(&lanes) {
            let ts = us(start, clock_hz);
            body.push((
                ts,
                Json::obj(vec![
                    ("ph", Json::str("X")),
                    ("name", Json::str(format!("s{sample}"))),
                    ("cat", Json::str("lifetime")),
                    ("pid", Json::num(PID_SAMPLES as f64)),
                    ("tid", Json::num(lane as f64)),
                    ("ts", Json::num(ts)),
                    ("dur", Json::num(us(end, clock_hz) - ts)),
                    ("args", Json::obj(vec![("sample", Json::num(sample as f64))])),
                ]),
            ));
        }
    }

    // Per-exit instants.
    let mut exit_stages: Vec<u32> = exits.iter().map(|&(_, s, _)| s).collect();
    exit_stages.sort_unstable();
    exit_stages.dedup();
    for stage in &exit_stages {
        out.push(meta(
            PID_EXITS,
            Some(*stage),
            "thread_name",
            &format!("exit{stage}"),
        ));
    }
    exits.sort_by_key(|&(sample, _, t)| (t, sample));
    for &(sample, stage, t) in &exits {
        let ts = us(t, clock_hz);
        body.push((
            ts,
            Json::obj(vec![
                ("ph", Json::str("i")),
                ("name", Json::str(format!("exit{stage}"))),
                ("cat", Json::str("exit")),
                ("s", Json::str("t")),
                ("pid", Json::num(PID_EXITS as f64)),
                ("tid", Json::num(stage as f64)),
                ("ts", Json::num(ts)),
                ("args", Json::obj(vec![("sample", Json::num(sample as f64))])),
            ]),
        ));
    }

    // Control: window spans, throughput counter, retune instants,
    // threshold counters.
    if !windows.is_empty() || !retunes.is_empty() {
        out.push(meta(PID_CONTROL, Some(0), "thread_name", "windows"));
    }
    windows.sort_by_key(|w| w.0);
    for &(window, start_sample, len, t_start, t_end, sps, ref reach) in &windows {
        let ts = us(t_start, clock_hz);
        body.push((
            ts,
            Json::obj(vec![
                ("ph", Json::str("X")),
                ("name", Json::str(format!("w{window}"))),
                ("cat", Json::str("window")),
                ("pid", Json::num(PID_CONTROL as f64)),
                ("tid", Json::num(0.0)),
                ("ts", Json::num(ts)),
                ("dur", Json::num(us(t_end, clock_hz) - ts)),
                (
                    "args",
                    Json::obj(vec![
                        ("window", Json::num(window as f64)),
                        ("start_sample", Json::num(start_sample as f64)),
                        ("len", Json::num(len as f64)),
                        ("throughput_sps", Json::num(sps)),
                        (
                            "reach",
                            Json::arr(reach.iter().map(|&r| Json::num(r))),
                        ),
                    ]),
                ),
            ]),
        ));
        body.push((
            ts,
            counter(PID_CONTROL, "throughput_sps", ts, vec![("sps", sps)]),
        ));
    }
    retunes.sort_by_key(|r| (r.1, r.0));
    for (window, t, thresholds, n) in &retunes {
        let ts = us(*t, clock_hz);
        body.push((
            ts,
            Json::obj(vec![
                ("ph", Json::str("i")),
                ("name", Json::str(format!("retune w{window}"))),
                ("cat", Json::str("control")),
                ("s", Json::str("p")),
                ("pid", Json::num(PID_CONTROL as f64)),
                ("tid", Json::num(0.0)),
                ("ts", Json::num(ts)),
                (
                    "args",
                    Json::obj(vec![
                        (
                            "thresholds",
                            Json::arr(thresholds.iter().map(|&v| Json::num(v))),
                        ),
                        ("retunes", Json::num(*n as f64)),
                    ]),
                ),
            ]),
        ));
        let series: Vec<(String, f64)> = thresholds
            .iter()
            .enumerate()
            .map(|(i, &v)| (format!("thr{i}"), v))
            .collect();
        body.push((
            ts,
            counter(
                PID_CONTROL,
                "thresholds",
                ts,
                series.iter().map(|(k, v)| (k.as_str(), *v)).collect(),
            ),
        ));
    }

    // Degradation instants (shed / forced exits / worker stalls and
    // restarts) on their own control-process lane. The meta row is
    // emitted only when degradation happened, so fault-free exports
    // stay byte-identical to the pre-degradation format.
    if !degradation.is_empty() {
        out.push(meta(PID_CONTROL, Some(1), "thread_name", "degradation"));
        degradation.sort_by(|a, b| (a.0, &a.1).cmp(&(b.0, &b.1)));
        for (t, name, arg, value) in &degradation {
            let ts = us(*t, clock_hz);
            body.push((
                ts,
                Json::obj(vec![
                    ("ph", Json::str("i")),
                    ("name", Json::str(name.clone())),
                    ("cat", Json::str("degradation")),
                    ("s", Json::str("t")),
                    ("pid", Json::num(PID_CONTROL as f64)),
                    ("tid", Json::num(1.0)),
                    ("ts", Json::num(ts)),
                    ("args", Json::obj(vec![(*arg, Json::num(*value))])),
                ]),
            ));
        }
    }

    // Stable sort keeps same-ts events in emission order (B before its
    // zero-length E, window span before its counter, ...).
    body.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    out.extend(body.into_iter().map(|(_, ev)| ev));

    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::Arr(out)),
    ])
}

/// Serialize a trace document for `trace.json` (pretty, so goldens
/// diff readably).
pub fn write_chrome_trace(events: &[TraceEvent], clock_hz: f64) -> String {
    let mut s = export_chrome_trace(events, clock_hz).to_string_pretty();
    s.push('\n');
    s
}

/// Summary returned by [`validate_chrome_trace`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChromeTraceStats {
    /// All events, metadata included.
    pub events: usize,
    /// Distinct (pid, tid) tracks seen on non-metadata events.
    pub tracks: usize,
    /// `X` (complete) spans.
    pub spans: usize,
    /// Matched `B`/`E` pairs.
    pub begin_end_pairs: usize,
    /// Flow ids with a start and an end.
    pub flows: usize,
    /// Counter samples.
    pub counters: usize,
    /// Instant events.
    pub instants: usize,
}

/// Validate Chrome-trace JSON text: well-formed JSON with a
/// `traceEvents` array, every event carrying `ph`/`name` (plus numeric
/// `pid`/`tid`/`ts` off the metadata path), non-decreasing timestamps
/// per (pid, tid) track, balanced `B`/`E` spans per track, non-negative
/// `X` durations, and every flow id opened exactly once (`s`) and
/// closed exactly once (`f`) in order. This is the schema gate CI runs
/// against the emitted `trace.json`.
pub fn validate_chrome_trace(text: &str) -> anyhow::Result<ChromeTraceStats> {
    let doc = json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let events = doc
        .req("traceEvents")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("traceEvents is not an array"))?;

    let mut stats = ChromeTraceStats {
        events: events.len(),
        ..Default::default()
    };
    let mut last_ts: BTreeMap<(i64, i64), f64> = BTreeMap::new();
    let mut depth: BTreeMap<(i64, i64), i64> = BTreeMap::new();
    // flow id -> (starts, ends, last ts)
    let mut flows: BTreeMap<i64, (u32, u32, f64)> = BTreeMap::new();

    for (i, ev) in events.iter().enumerate() {
        let fail = |msg: String| anyhow::anyhow!("event {i}: {msg}");
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| fail("missing ph".into()))?;
        if ev.get("name").and_then(Json::as_str).is_none() {
            return Err(fail("missing name".into()));
        }
        if ph == "M" {
            continue;
        }
        let pid = ev
            .get("pid")
            .and_then(Json::as_f64)
            .ok_or_else(|| fail("missing pid".into()))? as i64;
        let tid = ev.get("tid").and_then(Json::as_f64).unwrap_or(0.0) as i64;
        let ts = ev
            .get("ts")
            .and_then(Json::as_f64)
            .ok_or_else(|| fail("missing ts".into()))?;
        let track = (pid, tid);
        if let Some(&prev) = last_ts.get(&track) {
            if ts < prev {
                return Err(fail(format!(
                    "track ({pid},{tid}) timestamp regressed: {prev} -> {ts}"
                )));
            }
        }
        last_ts.insert(track, ts);

        match ph {
            "X" => {
                let dur = ev
                    .get("dur")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| fail("X without dur".into()))?;
                if dur < 0.0 {
                    return Err(fail(format!("negative dur {dur}")));
                }
                stats.spans += 1;
            }
            "B" => {
                *depth.entry(track).or_insert(0) += 1;
            }
            "E" => {
                let d = depth.entry(track).or_insert(0);
                *d -= 1;
                if *d < 0 {
                    return Err(fail(format!(
                        "track ({pid},{tid}) E without matching B"
                    )));
                }
                stats.begin_end_pairs += 1;
            }
            "C" => stats.counters += 1,
            "i" | "I" => stats.instants += 1,
            "s" | "t" | "f" => {
                let id = ev
                    .get("id")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| fail("flow without id".into()))? as i64;
                let entry = flows.entry(id).or_insert((0, 0, ts));
                if ts < entry.2 {
                    return Err(fail(format!("flow {id} timestamp regressed")));
                }
                entry.2 = ts;
                match ph {
                    "s" => entry.0 += 1,
                    "f" => entry.1 += 1,
                    _ => {}
                }
                if entry.0 > 1 || entry.1 > 1 {
                    return Err(fail(format!("flow {id} opened/closed twice")));
                }
                if entry.1 == 1 && entry.0 == 0 {
                    return Err(fail(format!("flow {id} closed before opening")));
                }
            }
            other => {
                return Err(fail(format!("unsupported phase {other:?}")));
            }
        }
    }

    for ((pid, tid), d) in &depth {
        if *d != 0 {
            anyhow::bail!("track ({pid},{tid}) has {d} unclosed B spans");
        }
    }
    for (id, (s, f, _)) in &flows {
        if *s != 1 || *f != 1 {
            anyhow::bail!("flow {id} not balanced (starts {s}, ends {f})");
        }
    }
    stats.tracks = last_ts.len();
    stats.flows = flows.len();
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_stream() -> Vec<TraceEvent> {
        vec![
            TraceEvent::SampleAdmitted { sample: 0, t: 2 },
            TraceEvent::SectionEnter { sample: 0, section: 0, t: 2 },
            TraceEvent::SectionExit { sample: 0, section: 0, t: 10 },
            TraceEvent::SampleAdmitted { sample: 1, t: 4 },
            TraceEvent::SectionEnter { sample: 1, section: 0, t: 4 },
            TraceEvent::SectionExit { sample: 1, section: 0, t: 12 },
            TraceEvent::BufferStalled {
                buffer: 0,
                sample: 1,
                t: 10,
                cycles: 3,
            },
            TraceEvent::BufferDrained {
                buffer: 0,
                sample: 0,
                enter: 10,
                leave: 14,
                dropped: false,
            },
            TraceEvent::SectionEnter { sample: 0, section: 1, t: 15 },
            TraceEvent::SectionExit { sample: 0, section: 1, t: 30 },
            TraceEvent::ExitTaken { sample: 1, stage: 0, t: 13 },
            TraceEvent::ExitTaken { sample: 0, stage: 1, t: 30 },
            TraceEvent::SampleRetired { sample: 1, t: 16 },
            TraceEvent::SampleRetired { sample: 0, t: 33 },
        ]
    }

    #[test]
    fn export_validates() {
        let text = write_chrome_trace(&small_stream(), 1e6);
        let stats = validate_chrome_trace(&text).expect("valid trace");
        // 2 sec0 spans + 1 sec1 span + 2 lifetime spans.
        assert_eq!(stats.spans, 5);
        assert_eq!(stats.begin_end_pairs, 1);
        // Sample 0 crosses two sections -> one flow; sample 1 has a
        // single hop -> no flow.
        assert_eq!(stats.flows, 1);
        assert_eq!(stats.instants, 2);
        // Occupancy synthesised from the drain interval: +1 then -1.
        assert_eq!(stats.counters, 2);
    }

    #[test]
    fn lanes_pack_overlaps() {
        // Two overlapping spans need two lanes; a third after both fits
        // back on lane 0.
        let lanes = assign_lanes(&[(0, 10), (5, 12), (12, 20)]);
        assert_eq!(lanes, vec![0, 1, 0]);
    }

    #[test]
    fn export_is_deterministic() {
        let a = write_chrome_trace(&small_stream(), 125e6);
        let b = write_chrome_trace(&small_stream(), 125e6);
        assert_eq!(a, b);
    }

    #[test]
    fn validator_rejects_unbalanced() {
        let text = r#"{"traceEvents":[
            {"ph":"B","name":"x","pid":0,"tid":0,"ts":1}
        ]}"#;
        assert!(validate_chrome_trace(text).is_err());
        let text = r#"{"traceEvents":[
            {"ph":"X","name":"x","pid":0,"tid":0,"ts":5,"dur":1},
            {"ph":"X","name":"y","pid":0,"tid":0,"ts":4,"dur":1}
        ]}"#;
        assert!(validate_chrome_trace(text).is_err(), "ts regression");
    }
}
