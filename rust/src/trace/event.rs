//! The structured event model and the sink contract.
//!
//! Every per-sample observable the simulator, the closed-loop drift
//! harness, and the serving front end produce is expressed as one
//! [`TraceEvent`]. Producers write events through the [`TraceSink`]
//! trait; the default [`NullSink`] reports `enabled() == false`, and
//! every emission site is gated on that flag **before** constructing
//! the event, so a disabled run performs no event allocation and no
//! work beyond one predictable branch — the zero-cost-when-disabled
//! rule (DESIGN.md §9). The [`Recorder`] is a bounded ring buffer:
//! when full it drops the *oldest* events (keeping the tail of the
//! run, which is where drift investigations look) and counts the
//! drops.
//!
//! Timestamps are producer-relative `u64` ticks: simulator events use
//! schedule cycles, server events use microseconds since server start.
//! The exporter converts ticks to trace microseconds with the
//! producer's clock (`clock_hz`; servers pass `1e6`).

use std::collections::VecDeque;

/// One structured trace event. Sample ids are batch indices in the
/// simulator and request ids in the server; `stage`/`section`/`buffer`
/// use the design's indexing (exit `i` guards Conditional Buffer `i`,
/// the final classifier is section `n_sections - 1`).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// Sample's DMA-in completed (simulator) or its request entered the
    /// stage-0 worker (server) at `t`.
    SampleAdmitted { sample: u64, t: u64 },
    /// Sample issued into backbone section `section` at `t`.
    SectionEnter { sample: u64, section: u32, t: u64 },
    /// Sample's section `section` compute finished (split write, or the
    /// final classifier's result) at `t`.
    SectionExit { sample: u64, section: u32, t: u64 },
    /// Sample completed at pipeline path `stage` (exit index; the final
    /// classifier is `n_sections - 1`) at `t`. Exactly one per sample.
    ExitTaken { sample: u64, stage: u32, t: u64 },
    /// Sample's classification left the output DMA at `t` (simulator
    /// only; server completions are the `ExitTaken` events).
    SampleRetired { sample: u64, t: u64 },
    /// The section feeding Conditional Buffer `buffer` stalled on a
    /// full buffer: `cycles` cycles starting at `t`.
    BufferStalled {
        buffer: u32,
        sample: u64,
        t: u64,
        cycles: u64,
    },
    /// A residency interval of Conditional Buffer `buffer` ended:
    /// `sample` occupied a slot from `enter` to `leave`. `dropped` is
    /// the easy-path address-invalidation drop; `!dropped` means the
    /// sample was drained into the next section.
    BufferDrained {
        buffer: u32,
        sample: u64,
        enter: u64,
        leave: u64,
        dropped: bool,
    },
    /// Instantaneous occupancy of forwarding queue / buffer `buffer`
    /// (server backpressure watermark; rendered as a counter track).
    BufferOccupancy { buffer: u32, t: u64, occupancy: u32 },
    /// A `ThresholdPolicy` retuned its thresholds during reporting
    /// window `window`; `thresholds` is the post-retune operating
    /// point, `retunes` how many retunes the window performed.
    ThresholdRetuned {
        window: u32,
        t: u64,
        thresholds: Vec<f64>,
        retunes: u64,
    },
    /// Closed-loop reporting-window statistics (one per window).
    WindowStats {
        window: u32,
        start_sample: u64,
        len: u32,
        t_start: u64,
        t_end: u64,
        throughput_sps: f64,
        reach: Vec<f64>,
    },
    /// Admission control shed a sample at `t` (rejected at submit or
    /// spilled to the baseline path; never entered the staged pipeline).
    SampleShed { sample: u64, t: u64 },
    /// A sample past its deadline was forced out at exit `stage`'s
    /// decision point at `t` (overload shedding via forced early exit).
    DeadlineForcedExit { sample: u64, stage: u32, t: u64 },
    /// Stage `stage`'s worker stalled for `millis` ms starting at `t`
    /// (injected by a `ServeFaultPlan`, or observed pathology).
    WorkerStalled { stage: u32, t: u64, millis: u64 },
    /// Stage `stage`'s supervisor caught a worker panic and respawned
    /// it at `t`; `restarts` is the stage's cumulative restart count.
    WorkerRestarted { stage: u32, t: u64, restarts: u64 },
}

impl TraceEvent {
    /// The event's timestamp in producer ticks (`t_start` for window
    /// spans, the residency end for buffer drains).
    pub fn timestamp(&self) -> u64 {
        match *self {
            TraceEvent::SampleAdmitted { t, .. }
            | TraceEvent::SectionEnter { t, .. }
            | TraceEvent::SectionExit { t, .. }
            | TraceEvent::ExitTaken { t, .. }
            | TraceEvent::SampleRetired { t, .. }
            | TraceEvent::BufferStalled { t, .. }
            | TraceEvent::BufferOccupancy { t, .. }
            | TraceEvent::ThresholdRetuned { t, .. }
            | TraceEvent::SampleShed { t, .. }
            | TraceEvent::DeadlineForcedExit { t, .. }
            | TraceEvent::WorkerStalled { t, .. }
            | TraceEvent::WorkerRestarted { t, .. } => t,
            TraceEvent::BufferDrained { leave, .. } => leave,
            TraceEvent::WindowStats { t_start, .. } => t_start,
        }
    }
}

/// Where producers write trace events.
///
/// Contract: emission sites MUST gate on [`TraceSink::enabled`] before
/// constructing an event (`if sink.enabled() { sink.emit(...) }`), so
/// that a disabled sink costs one branch and zero allocation — the
/// `NullSink` path of `simulate_multi` is property-tested bit-identical
/// and allocation-free against the pre-tracing simulator.
pub trait TraceSink {
    /// Whether events should be constructed and emitted at all.
    fn enabled(&self) -> bool;

    /// Record one event. Only called when [`TraceSink::enabled`] is
    /// true (callers gate; implementations need not re-check).
    fn emit(&mut self, ev: TraceEvent);
}

/// The default sink: tracing off. `enabled()` is `false`, so no
/// emission site ever constructs an event through it.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    #[inline(always)]
    fn enabled(&self) -> bool {
        false
    }

    #[inline(always)]
    fn emit(&mut self, _ev: TraceEvent) {}
}

/// Bounded ring-buffer sink. Holds at most `capacity` events; once
/// full, each new event evicts the oldest (drift debugging wants the
/// tail of the run) and increments [`Recorder::dropped`].
#[derive(Debug)]
pub struct Recorder {
    capacity: usize,
    events: VecDeque<TraceEvent>,
    dropped: u64,
}

/// Default recorder capacity (events). A traced 8k-sample three-exit
/// closed-loop run emits ~10 events per sample, so the default holds
/// runs an order of magnitude larger before wrapping.
pub const DEFAULT_RECORDER_CAPACITY: usize = 1 << 20;

impl Default for Recorder {
    fn default() -> Self {
        Recorder::new(DEFAULT_RECORDER_CAPACITY)
    }
}

impl Recorder {
    /// A recorder holding at most `capacity` events (min 1).
    pub fn new(capacity: usize) -> Recorder {
        Recorder {
            capacity: capacity.max(1),
            events: VecDeque::new(),
            dropped: 0,
        }
    }

    /// Record one event, evicting the oldest when full.
    pub fn record(&mut self, ev: TraceEvent) {
        if self.events.len() == self.capacity {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }

    /// Events currently held, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter()
    }

    /// Number of events currently held.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events evicted because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Drop every held event and reset the drop counter (capacity is
    /// kept; used by benches re-tracing into one recorder).
    pub fn clear(&mut self) {
        self.events.clear();
        self.dropped = 0;
    }

    /// Move the held events out as a contiguous, oldest-first vec.
    pub fn take_events(&mut self) -> Vec<TraceEvent> {
        self.events.drain(..).collect()
    }

    /// Copy of the held events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.iter().cloned().collect()
    }
}

impl TraceSink for Recorder {
    #[inline]
    fn enabled(&self) -> bool {
        true
    }

    #[inline]
    fn emit(&mut self, ev: TraceEvent) {
        self.record(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_sink_is_disabled() {
        let s = NullSink;
        assert!(!s.enabled());
    }

    #[test]
    fn recorder_keeps_tail_and_counts_drops() {
        let mut r = Recorder::new(3);
        for i in 0..5u64 {
            r.record(TraceEvent::SampleAdmitted { sample: i, t: i });
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let kept: Vec<u64> = r
            .iter()
            .map(|e| match e {
                TraceEvent::SampleAdmitted { sample, .. } => *sample,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(kept, vec![2, 3, 4], "oldest events evicted first");
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn recorder_take_preserves_order() {
        let mut r = Recorder::new(8);
        r.record(TraceEvent::SampleAdmitted { sample: 0, t: 10 });
        r.record(TraceEvent::ExitTaken { sample: 0, stage: 1, t: 42 });
        let evs = r.take_events();
        assert_eq!(evs.len(), 2);
        assert!(r.is_empty());
        assert_eq!(evs[0].timestamp(), 10);
        assert_eq!(evs[1].timestamp(), 42);
    }

    #[test]
    fn timestamps_pick_the_track_anchor() {
        let d = TraceEvent::BufferDrained {
            buffer: 0,
            sample: 1,
            enter: 5,
            leave: 9,
            dropped: true,
        };
        assert_eq!(d.timestamp(), 9);
        let w = TraceEvent::WindowStats {
            window: 0,
            start_sample: 0,
            len: 4,
            t_start: 100,
            t_end: 200,
            throughput_sps: 1.0,
            reach: vec![0.4],
        };
        assert_eq!(w.timestamp(), 100);
    }
}
