//! Span aggregation: turn a raw event stream back into the summary
//! numbers a terminal wants — per-exit latency distributions, per-buffer
//! stall totals, and closed-loop reconvergence time after a drift step.
//!
//! The aggregation works from the same flat [`TraceEvent`] stream the
//! exporter consumes, so `atheena trace` computes both from one
//! recorder pass. All latencies are reported in producer ticks AND in
//! microseconds (via the producer clock), because the table is read
//! next to Perfetto's microsecond timeline.

use std::collections::BTreeMap;

use super::event::TraceEvent;

/// Latency distribution for one exit stage.
#[derive(Clone, Debug, PartialEq)]
pub struct ExitLatency {
    /// Exit stage index (the final classifier is the last stage).
    pub stage: u32,
    /// Samples that completed at this stage.
    pub count: u64,
    /// Fraction of all completed samples.
    pub rate: f64,
    pub min: u64,
    pub max: u64,
    pub mean: f64,
    pub p50: u64,
    pub p99: u64,
    /// Power-of-two latency histogram: `histogram[i]` counts samples
    /// with latency in `[2^i, 2^(i+1))` ticks (bucket 0 is `[0, 2)`).
    pub histogram: Vec<u64>,
}

/// Stall/residency totals for one Conditional Buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct BufferSummary {
    pub buffer: u32,
    /// Number of producer stall episodes.
    pub stall_events: u64,
    /// Total cycles the producing section spent blocked on this buffer.
    pub stall_cycles: u64,
    /// Residency intervals that ended in a drain to the next section.
    pub drained: u64,
    /// Residency intervals that ended in an easy-path drop.
    pub dropped: u64,
    /// Longest single residency (ticks).
    pub max_residency: u64,
    /// Peak synthesised occupancy (from residency sweep or direct
    /// occupancy samples).
    pub peak_occupancy: u32,
}

/// Closed-loop control summary.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ControlSummary {
    pub windows: u64,
    pub retunes: u64,
    /// Window index of the first retune, if any.
    pub first_retune_window: Option<u32>,
    /// Ticks from the first retune to the last — how long the
    /// controller took to reconverge after the drift step. `Some(0)`
    /// means a single corrective retune.
    pub reconverge_ticks: Option<u64>,
    /// Same span counted in windows.
    pub reconverge_windows: Option<u32>,
    pub mean_throughput_sps: f64,
}

/// Degradation totals (DESIGN.md §12): admission shedding, deadline
/// forced exits, and supervisor activity. All-zero on a healthy run —
/// the renderer omits the section entirely then, keeping fault-free
/// summaries byte-identical to the pre-degradation format.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DegradationSummary {
    /// Samples shed by admission control (rejected or spilled).
    pub shed: u64,
    /// Samples forced out at an earlier exit by their deadline.
    pub forced_exits: u64,
    /// Worker stall episodes.
    pub worker_stalls: u64,
    /// Total stalled milliseconds across all workers.
    pub stall_millis: u64,
    /// Supervisor worker restarts.
    pub worker_restarts: u64,
}

impl DegradationSummary {
    /// True when nothing degraded (the renderer's omission gate).
    pub fn is_clean(&self) -> bool {
        self.shed == 0
            && self.forced_exits == 0
            && self.worker_stalls == 0
            && self.worker_restarts == 0
    }
}

/// Everything `atheena trace` prints.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSummary {
    /// Producer tick rate (for tick → µs conversion in rendering).
    pub clock_hz: f64,
    /// Samples with an `ExitTaken` event.
    pub samples: u64,
    pub exits: Vec<ExitLatency>,
    pub buffers: Vec<BufferSummary>,
    pub control: ControlSummary,
    /// Shedding / forced-exit / supervisor totals (all-zero when the
    /// run was healthy).
    pub degradation: DegradationSummary,
    /// Events evicted by the recorder ring (0 unless the run
    /// out-sized the ring; non-zero means the head of the run is
    /// missing from the aggregation).
    pub dropped_events: u64,
}

fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn log2_bucket(v: u64) -> usize {
    (64 - v.max(1).leading_zeros() as usize).saturating_sub(1)
}

impl TraceSummary {
    /// Aggregate a flat event stream. `dropped_events` is the
    /// recorder's drop count (pass 0 for an unbounded capture).
    pub fn from_events(events: &[TraceEvent], clock_hz: f64, dropped_events: u64) -> TraceSummary {
        let mut admits: BTreeMap<u64, u64> = BTreeMap::new();
        let mut retires: BTreeMap<u64, u64> = BTreeMap::new();
        // sample -> (stage, exit t)
        let mut taken: BTreeMap<u64, (u32, u64)> = BTreeMap::new();
        let mut buffers: BTreeMap<u32, BufferSummary> = BTreeMap::new();
        let mut occupancy_edges: BTreeMap<u32, Vec<(u64, i32)>> = BTreeMap::new();
        let mut direct_occupancy: BTreeMap<u32, u32> = BTreeMap::new();
        let mut control = ControlSummary::default();
        let mut degradation = DegradationSummary::default();
        let mut throughput_sum = 0.0;
        let mut first_retune: Option<(u32, u64)> = None;
        let mut last_retune: Option<(u32, u64)> = None;

        let buf_entry = |m: &mut BTreeMap<u32, BufferSummary>, b: u32| {
            m.entry(b).or_insert_with(|| BufferSummary {
                buffer: b,
                stall_events: 0,
                stall_cycles: 0,
                drained: 0,
                dropped: 0,
                max_residency: 0,
                peak_occupancy: 0,
            })
        };

        for ev in events {
            match ev {
                TraceEvent::SampleAdmitted { sample, t } => {
                    admits.insert(*sample, *t);
                }
                TraceEvent::SampleRetired { sample, t } => {
                    retires.insert(*sample, *t);
                }
                TraceEvent::ExitTaken { sample, stage, t } => {
                    taken.insert(*sample, (*stage, *t));
                }
                TraceEvent::BufferStalled {
                    buffer, cycles, ..
                } => {
                    let b = buf_entry(&mut buffers, *buffer);
                    b.stall_events += 1;
                    b.stall_cycles += cycles;
                }
                TraceEvent::BufferDrained {
                    buffer,
                    enter,
                    leave,
                    dropped,
                    ..
                } => {
                    let b = buf_entry(&mut buffers, *buffer);
                    if *dropped {
                        b.dropped += 1;
                    } else {
                        b.drained += 1;
                    }
                    b.max_residency = b.max_residency.max(leave.saturating_sub(*enter));
                    let edges = occupancy_edges.entry(*buffer).or_default();
                    edges.push((*enter, 1));
                    edges.push((*leave, -1));
                }
                TraceEvent::BufferOccupancy {
                    buffer,
                    occupancy,
                    ..
                } => {
                    buf_entry(&mut buffers, *buffer);
                    let peak = direct_occupancy.entry(*buffer).or_insert(0);
                    *peak = (*peak).max(*occupancy);
                }
                TraceEvent::ThresholdRetuned { window, t, .. } => {
                    if first_retune.is_none() {
                        first_retune = Some((*window, *t));
                    }
                    last_retune = Some((*window, *t));
                }
                TraceEvent::WindowStats {
                    throughput_sps, ..
                } => {
                    control.windows += 1;
                    throughput_sum += throughput_sps;
                }
                TraceEvent::SampleShed { .. } => {
                    degradation.shed += 1;
                }
                TraceEvent::DeadlineForcedExit { .. } => {
                    degradation.forced_exits += 1;
                }
                TraceEvent::WorkerStalled { millis, .. } => {
                    degradation.worker_stalls += 1;
                    degradation.stall_millis += millis;
                }
                TraceEvent::WorkerRestarted { .. } => {
                    degradation.worker_restarts += 1;
                }
                TraceEvent::SectionEnter { .. } | TraceEvent::SectionExit { .. } => {}
            }
        }
        // Retune count: the per-window `retunes` field is cumulative
        // within a window; count the events themselves.
        control.retunes = events
            .iter()
            .filter(|e| matches!(e, TraceEvent::ThresholdRetuned { .. }))
            .count() as u64;
        if let (Some((fw, ft)), Some((lw, lt))) = (first_retune, last_retune) {
            control.first_retune_window = Some(fw);
            control.reconverge_ticks = Some(lt.saturating_sub(ft));
            control.reconverge_windows = Some(lw.saturating_sub(fw));
        }
        if control.windows > 0 {
            control.mean_throughput_sps = throughput_sum / control.windows as f64;
        }

        // Peak occupancy: sweep residency edges (leave before enter on
        // ties, matching the exporter), else direct samples.
        for (buf, edges) in &mut occupancy_edges {
            edges.sort_by_key(|&(t, delta)| (t, delta));
            let mut level = 0i32;
            let mut peak = 0i32;
            for &(_, delta) in edges.iter() {
                level += delta;
                peak = peak.max(level);
            }
            if let Some(b) = buffers.get_mut(buf) {
                b.peak_occupancy = peak.max(0) as u32;
            }
        }
        for (buf, peak) in &direct_occupancy {
            if let Some(b) = buffers.get_mut(buf) {
                b.peak_occupancy = b.peak_occupancy.max(*peak);
            }
        }

        // Per-exit latency: admission to retirement (simulator) or to
        // the exit decision when no retirement was captured (server).
        let mut per_exit: BTreeMap<u32, Vec<u64>> = BTreeMap::new();
        for (sample, &(stage, t_exit)) in &taken {
            let done = retires.get(sample).copied().unwrap_or(t_exit);
            let lat = match admits.get(sample) {
                Some(&t_in) => done.saturating_sub(t_in),
                // Admission evicted by the ring: skip rather than
                // fabricate a latency.
                None => continue,
            };
            per_exit.entry(stage).or_default().push(lat);
        }
        let total: u64 = per_exit.values().map(|v| v.len() as u64).sum();
        let exits = per_exit
            .into_iter()
            .map(|(stage, mut lats)| {
                lats.sort_unstable();
                let count = lats.len() as u64;
                let sum: u64 = lats.iter().sum();
                let mut histogram = vec![0u64; log2_bucket(*lats.last().unwrap()) + 1];
                for &l in &lats {
                    histogram[log2_bucket(l)] += 1;
                }
                ExitLatency {
                    stage,
                    count,
                    rate: count as f64 / total.max(1) as f64,
                    min: lats[0],
                    max: *lats.last().unwrap(),
                    mean: sum as f64 / count as f64,
                    p50: percentile(&lats, 0.50),
                    p99: percentile(&lats, 0.99),
                    histogram,
                }
            })
            .collect();

        TraceSummary {
            clock_hz,
            samples: taken.len() as u64,
            exits,
            buffers: buffers.into_values().collect(),
            control,
            degradation,
            dropped_events,
        }
    }

    /// Exit counts keyed by stage (for reconciling against
    /// `SimMetrics::exit_rates` in tests).
    pub fn exit_counts(&self) -> BTreeMap<u32, u64> {
        self.exits.iter().map(|e| (e.stage, e.count)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_buckets() {
        assert_eq!(log2_bucket(0), 0);
        assert_eq!(log2_bucket(1), 0);
        assert_eq!(log2_bucket(2), 1);
        assert_eq!(log2_bucket(3), 1);
        assert_eq!(log2_bucket(4), 2);
        assert_eq!(log2_bucket(1023), 9);
        assert_eq!(log2_bucket(1024), 10);
    }

    #[test]
    fn aggregates_exits_and_buffers() {
        let evs = vec![
            TraceEvent::SampleAdmitted { sample: 0, t: 0 },
            TraceEvent::SampleAdmitted { sample: 1, t: 5 },
            TraceEvent::SampleAdmitted { sample: 2, t: 10 },
            TraceEvent::ExitTaken { sample: 0, stage: 0, t: 8 },
            TraceEvent::ExitTaken { sample: 1, stage: 1, t: 40 },
            TraceEvent::ExitTaken { sample: 2, stage: 0, t: 20 },
            TraceEvent::SampleRetired { sample: 0, t: 10 },
            TraceEvent::SampleRetired { sample: 1, t: 45 },
            TraceEvent::SampleRetired { sample: 2, t: 22 },
            TraceEvent::BufferStalled {
                buffer: 0,
                sample: 1,
                t: 6,
                cycles: 4,
            },
            TraceEvent::BufferDrained {
                buffer: 0,
                sample: 0,
                enter: 2,
                leave: 9,
                dropped: true,
            },
            TraceEvent::BufferDrained {
                buffer: 0,
                sample: 1,
                enter: 6,
                leave: 12,
                dropped: false,
            },
        ];
        let s = TraceSummary::from_events(&evs, 125e6, 0);
        assert_eq!(s.samples, 3);
        assert_eq!(s.exits.len(), 2);
        let e0 = &s.exits[0];
        assert_eq!((e0.stage, e0.count), (0, 2));
        assert_eq!((e0.min, e0.max), (10, 12)); // retire - admit
        assert!((e0.rate - 2.0 / 3.0).abs() < 1e-12);
        let e1 = &s.exits[1];
        assert_eq!((e1.stage, e1.count, e1.min), (1, 1, 40));
        let b = &s.buffers[0];
        assert_eq!(b.stall_events, 1);
        assert_eq!(b.stall_cycles, 4);
        assert_eq!((b.drained, b.dropped), (1, 1));
        assert_eq!(b.max_residency, 7);
        assert_eq!(b.peak_occupancy, 2); // [6, 9) overlap
        assert_eq!(s.exit_counts().get(&0), Some(&2));
    }

    #[test]
    fn reconvergence_spans_retunes() {
        let evs = vec![
            TraceEvent::WindowStats {
                window: 0,
                start_sample: 0,
                len: 4,
                t_start: 0,
                t_end: 100,
                throughput_sps: 10.0,
                reach: vec![],
            },
            TraceEvent::ThresholdRetuned {
                window: 2,
                t: 250,
                thresholds: vec![0.5],
                retunes: 1,
            },
            TraceEvent::WindowStats {
                window: 1,
                start_sample: 4,
                len: 4,
                t_start: 100,
                t_end: 200,
                throughput_sps: 30.0,
                reach: vec![],
            },
            TraceEvent::ThresholdRetuned {
                window: 5,
                t: 600,
                thresholds: vec![0.6],
                retunes: 1,
            },
        ];
        let s = TraceSummary::from_events(&evs, 1e6, 0);
        assert_eq!(s.control.windows, 2);
        assert_eq!(s.control.retunes, 2);
        assert_eq!(s.control.first_retune_window, Some(2));
        assert_eq!(s.control.reconverge_ticks, Some(350));
        assert_eq!(s.control.reconverge_windows, Some(3));
        assert!((s.control.mean_throughput_sps - 20.0).abs() < 1e-12);
    }

    #[test]
    fn no_retunes_means_no_reconvergence() {
        let s = TraceSummary::from_events(&[], 1e6, 3);
        assert_eq!(s.control.reconverge_ticks, None);
        assert_eq!(s.dropped_events, 3);
        assert!(s.exits.is_empty());
        assert!(s.degradation.is_clean());
    }

    #[test]
    fn degradation_events_are_totalled() {
        let evs = vec![
            TraceEvent::SampleShed { sample: 3, t: 10 },
            TraceEvent::DeadlineForcedExit { sample: 4, stage: 0, t: 20 },
            TraceEvent::DeadlineForcedExit { sample: 5, stage: 1, t: 25 },
            TraceEvent::WorkerStalled { stage: 1, t: 30, millis: 40 },
            TraceEvent::WorkerRestarted { stage: 1, t: 70, restarts: 1 },
            TraceEvent::WorkerRestarted { stage: 2, t: 90, restarts: 1 },
        ];
        let s = TraceSummary::from_events(&evs, 1e6, 0);
        let d = &s.degradation;
        assert_eq!(d.shed, 1);
        assert_eq!(d.forced_exits, 2);
        assert_eq!((d.worker_stalls, d.stall_millis), (1, 40));
        assert_eq!(d.worker_restarts, 2);
        assert!(!d.is_clean());
    }
}
