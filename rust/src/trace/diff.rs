//! Trace diffing: align two pinned-seed trace streams and report the
//! first diverging event.
//!
//! Two runs of the simulator (or the closed-loop harness) under the
//! same seed and config must produce byte-identical event streams; a
//! divergence localises a nondeterminism bug or a semantic drift
//! between simulator cores to the first track/timestamp where the
//! streams disagree. The diff is a pure function over PR 6's event
//! model: events are grouped into the same logical tracks the Perfetto
//! exporter renders —
//!
//! - `samples`: [`TraceEvent::SampleAdmitted`] / [`TraceEvent::SampleRetired`]
//! - `section/{i}`: [`TraceEvent::SectionEnter`] / [`TraceEvent::SectionExit`]
//! - `exit/{stage}`: [`TraceEvent::ExitTaken`]
//! - `buffer/{i}`: [`TraceEvent::BufferStalled`] / [`TraceEvent::BufferDrained`]
//!   / [`TraceEvent::BufferOccupancy`]
//! - `control`: [`TraceEvent::ThresholdRetuned`] / [`TraceEvent::WindowStats`]
//!   / [`TraceEvent::WorkerStalled`] / [`TraceEvent::WorkerRestarted`]
//!
//! (Degradation sample events join their sample's track:
//! [`TraceEvent::SampleShed`] → `samples`,
//! [`TraceEvent::DeadlineForcedExit`] → `exit/{stage}`.)
//!
//! — then compared element-wise per track (producers emit each track in
//! deterministic order, so index `k` of a track in run A corresponds to
//! index `k` in run B). Among tracks that disagree, the reported
//! [`Divergence`] is the one whose diverging event has the smallest
//! timestamp (ties broken by track name), i.e. the *earliest* point the
//! runs split — everything after the first divergence is usually
//! cascade.
//!
//! [`diff_chrome_traces`] applies the same alignment to two exported
//! Chrome-trace JSON files (`atheena trace --out`), grouping
//! non-metadata events by `(pid, tid)` — so on-disk artifacts can be
//! diffed without re-running the producer. The CLI front end is
//! `atheena trace diff A.json B.json` (exit 1 on divergence, like
//! `diff(1)`).

use std::collections::BTreeMap;

use super::event::TraceEvent;
use crate::util::json::{parse, Json};

/// The first point where two trace streams disagree. `a`/`b` are the
/// rendered payloads of the two sides' events at the diverging index;
/// `None` means that side's track ended (the other stream has extra
/// events).
#[derive(Clone, Debug, PartialEq)]
pub struct Divergence {
    /// Logical track the divergence is on (`samples`, `section/1`,
    /// `exit/0`, `buffer/0`, `control` — or `pid/tid` for Chrome-JSON
    /// diffs).
    pub track: String,
    /// Element index within the track at which the streams disagree.
    pub index: usize,
    /// Timestamp of the diverging event (producer ticks for event
    /// streams, trace microseconds for Chrome-JSON diffs), taken from
    /// side A when present, else side B.
    pub timestamp: f64,
    /// Side A's event at `index`, or `None` if A's track ended first.
    pub a: Option<String>,
    /// Side B's event at `index`, or `None` if B's track ended first.
    pub b: Option<String>,
}

impl Divergence {
    /// Multi-line human rendering (the `trace diff` CLI output body).
    pub fn render(&self) -> String {
        format!(
            "first divergence: track {} event #{} (t = {})\n  A: {}\n  B: {}\n",
            self.track,
            self.index,
            self.timestamp,
            self.a.as_deref().unwrap_or("<track ended>"),
            self.b.as_deref().unwrap_or("<track ended>"),
        )
    }
}

/// The logical track an event belongs to (mirrors the Perfetto
/// exporter's process/thread layout).
fn track_key(ev: &TraceEvent) -> String {
    match ev {
        TraceEvent::SampleAdmitted { .. } | TraceEvent::SampleRetired { .. } => {
            "samples".to_string()
        }
        TraceEvent::SectionEnter { section, .. } | TraceEvent::SectionExit { section, .. } => {
            format!("section/{section}")
        }
        TraceEvent::ExitTaken { stage, .. } => format!("exit/{stage}"),
        TraceEvent::BufferStalled { buffer, .. }
        | TraceEvent::BufferDrained { buffer, .. }
        | TraceEvent::BufferOccupancy { buffer, .. } => format!("buffer/{buffer}"),
        TraceEvent::ThresholdRetuned { .. } | TraceEvent::WindowStats { .. } => {
            "control".to_string()
        }
        TraceEvent::SampleShed { .. } => "samples".to_string(),
        TraceEvent::DeadlineForcedExit { stage, .. } => format!("exit/{stage}"),
        TraceEvent::WorkerStalled { .. } | TraceEvent::WorkerRestarted { .. } => {
            "control".to_string()
        }
    }
}

fn group_events(evs: &[TraceEvent]) -> BTreeMap<String, Vec<&TraceEvent>> {
    let mut tracks: BTreeMap<String, Vec<&TraceEvent>> = BTreeMap::new();
    for ev in evs {
        tracks.entry(track_key(ev)).or_default().push(ev);
    }
    tracks
}

/// Generic per-track first-divergence scan. `tracks` pairs each track
/// key with that track's (A, B) element lists; `ts`/`render` project a
/// timestamp and payload from one element. Returns the divergence with
/// the smallest timestamp (ties → lexicographically first track).
fn earliest_divergence<T: PartialEq>(
    tracks: impl Iterator<Item = (String, Vec<T>, Vec<T>)>,
    ts: impl Fn(&T) -> f64,
    render: impl Fn(&T) -> String,
) -> Option<Divergence> {
    let mut best: Option<Divergence> = None;
    for (track, a, b) in tracks {
        let n = a.len().min(b.len());
        let idx = (0..n).find(|&i| a[i] != b[i]).or_else(|| {
            // One stream has extra events on this track.
            (a.len() != b.len()).then_some(n)
        });
        let Some(i) = idx else { continue };
        let ea = a.get(i);
        let eb = b.get(i);
        let t = ea.or(eb).map(&ts).unwrap_or(0.0);
        let cand = Divergence {
            track,
            index: i,
            timestamp: t,
            a: ea.map(&render),
            b: eb.map(&render),
        };
        let wins = match &best {
            None => true,
            Some(cur) => {
                cand.timestamp < cur.timestamp
                    || (cand.timestamp == cur.timestamp && cand.track < cur.track)
            }
        };
        if wins {
            best = Some(cand);
        }
    }
    best
}

/// First divergence between two event streams, or `None` when they are
/// identical (up to per-track ordering, which deterministic producers
/// fix). Pure; no IO.
pub fn first_divergence(a: &[TraceEvent], b: &[TraceEvent]) -> Option<Divergence> {
    let mut ta = group_events(a);
    let mut tb = group_events(b);
    let keys: Vec<String> = ta.keys().chain(tb.keys()).cloned().collect();
    let mut tracks = Vec::new();
    for k in keys {
        if ta.contains_key(&k) || tb.contains_key(&k) {
            let va = ta.remove(&k).unwrap_or_default();
            let vb = tb.remove(&k).unwrap_or_default();
            tracks.push((k, va, vb));
        }
    }
    earliest_divergence(
        tracks.into_iter(),
        |ev| ev.timestamp() as f64,
        |ev| format!("{ev:?}"),
    )
}

fn chrome_tracks(text: &str) -> anyhow::Result<BTreeMap<String, Vec<Json>>> {
    let root = parse(text).map_err(|e| anyhow::anyhow!("bad trace JSON: {e}"))?;
    let evs = root
        .req("traceEvents")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("traceEvents is not an array"))?;
    let mut tracks: BTreeMap<String, Vec<Json>> = BTreeMap::new();
    for ev in evs {
        // Metadata records only name tracks; they carry no timeline
        // payload and legitimately differ in emission order.
        if ev.get("ph").and_then(Json::as_str) == Some("M") {
            continue;
        }
        let pid = ev.get("pid").and_then(Json::as_f64).unwrap_or(-1.0);
        let tid = ev.get("tid").and_then(Json::as_f64).unwrap_or(-1.0);
        tracks
            .entry(format!("{pid:.0}/{tid:.0}"))
            .or_default()
            .push(ev.clone());
    }
    Ok(tracks)
}

/// First divergence between two exported Chrome-trace JSON documents
/// (the `atheena trace --out` artifact), aligning non-metadata events
/// by `(pid, tid)` track. Errors only on malformed JSON.
pub fn diff_chrome_traces(a_text: &str, b_text: &str) -> anyhow::Result<Option<Divergence>> {
    let mut ta = chrome_tracks(a_text)?;
    let mut tb = chrome_tracks(b_text)?;
    let keys: Vec<String> = ta.keys().chain(tb.keys()).cloned().collect();
    let mut tracks = Vec::new();
    for k in keys {
        if ta.contains_key(&k) || tb.contains_key(&k) {
            let va = ta.remove(&k).unwrap_or_default();
            let vb = tb.remove(&k).unwrap_or_default();
            tracks.push((k, va, vb));
        }
    }
    Ok(earliest_divergence(
        tracks.into_iter(),
        |ev| ev.get("ts").and_then(Json::as_f64).unwrap_or(0.0),
        |ev| ev.to_string_compact(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream() -> Vec<TraceEvent> {
        vec![
            TraceEvent::SampleAdmitted { sample: 0, t: 100 },
            TraceEvent::SectionEnter { sample: 0, section: 0, t: 100 },
            TraceEvent::SectionExit { sample: 0, section: 0, t: 250 },
            TraceEvent::ExitTaken { sample: 0, stage: 0, t: 370 },
            TraceEvent::SampleAdmitted { sample: 1, t: 200 },
            TraceEvent::SectionEnter { sample: 1, section: 0, t: 200 },
            TraceEvent::BufferStalled { buffer: 0, sample: 1, t: 300, cycles: 7 },
            TraceEvent::SampleRetired { sample: 0, t: 400 },
            TraceEvent::SampleRetired { sample: 1, t: 520 },
        ]
    }

    #[test]
    fn identical_streams_have_no_divergence() {
        let a = stream();
        assert_eq!(first_divergence(&a, &a.clone()), None);
        assert_eq!(first_divergence(&[], &[]), None);
    }

    #[test]
    fn hand_mutated_payload_is_localised() {
        let a = stream();
        let mut b = stream();
        // Mutate sample 1's stall duration — a payload change deep in
        // the stream, on the buffer/0 track.
        b[6] = TraceEvent::BufferStalled { buffer: 0, sample: 1, t: 300, cycles: 9 };
        let d = first_divergence(&a, &b).expect("must diverge");
        assert_eq!(d.track, "buffer/0");
        assert_eq!(d.index, 0);
        assert_eq!(d.timestamp, 300.0);
        assert!(d.a.as_deref().unwrap().contains("cycles: 7"), "{d:?}");
        assert!(d.b.as_deref().unwrap().contains("cycles: 9"), "{d:?}");
        assert!(d.render().contains("buffer/0"));
    }

    #[test]
    fn earliest_divergence_wins_across_tracks() {
        let a = stream();
        let mut b = stream();
        // Two mutations: a late samples-track change (t = 520) and an
        // earlier exit-track change (t = 370). The exit one must win.
        b[8] = TraceEvent::SampleRetired { sample: 1, t: 999 };
        b[3] = TraceEvent::ExitTaken { sample: 0, stage: 1, t: 370 };
        let d = first_divergence(&a, &b).expect("must diverge");
        // Stage is part of the track key, so the mutation shows up as
        // exit/0 present only in A (and exit/1 only in B) at t = 370 —
        // still earlier than the t = 520 samples divergence.
        assert_eq!(d.timestamp, 370.0);
        assert!(d.track.starts_with("exit/"), "{d:?}");
        assert!(d.a.is_none() || d.b.is_none());
    }

    #[test]
    fn truncated_stream_reports_missing_tail() {
        let a = stream();
        let b: Vec<TraceEvent> = stream()[..7].to_vec(); // drop both retirements
        let d = first_divergence(&a, &b).expect("must diverge");
        assert_eq!(d.track, "samples");
        assert_eq!(d.index, 2, "two admits precede the retirements");
        assert_eq!(d.timestamp, 400.0);
        assert!(d.b.is_none(), "B's samples track ended: {d:?}");
    }

    #[test]
    fn chrome_diff_aligns_by_pid_tid_and_skips_metadata() {
        let mk = |dur: f64, meta_name: &str| {
            Json::obj(vec![(
                "traceEvents",
                Json::arr(vec![
                    Json::obj(vec![
                        ("ph", Json::str("M")),
                        ("name", Json::str(meta_name)),
                        ("pid", Json::num(0.0)),
                    ]),
                    Json::obj(vec![
                        ("ph", Json::str("X")),
                        ("pid", Json::num(0.0)),
                        ("tid", Json::num(3.0)),
                        ("ts", Json::num(10.0)),
                        ("dur", Json::num(dur)),
                    ]),
                ]),
            )])
            .to_string_compact()
        };
        // Metadata-only difference: no divergence.
        let d = diff_chrome_traces(&mk(5.0, "alpha"), &mk(5.0, "beta")).unwrap();
        assert_eq!(d, None);
        // Duration difference on pid 0 / tid 3.
        let d = diff_chrome_traces(&mk(5.0, "alpha"), &mk(6.0, "alpha"))
            .unwrap()
            .expect("must diverge");
        assert_eq!(d.track, "0/3");
        assert_eq!(d.index, 0);
        assert_eq!(d.timestamp, 10.0);
        assert!(diff_chrome_traces("not json", "{}").is_err());
    }
}
