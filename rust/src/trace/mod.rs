//! Per-sample event tracing: structured events from the simulator, the
//! closed-loop drift harness, and the serving coordinator, exportable
//! as Chrome-trace/Perfetto JSON and reducible to terminal summaries.
//!
//! The subsystem has three layers:
//! - [`event`]: the [`TraceEvent`] model and the [`TraceSink`]
//!   contract. The default [`NullSink`] is zero-cost — every emission
//!   site gates on `sink.enabled()` before building an event, so
//!   untraced `simulate_multi` stays bit-identical and allocation-free
//!   (property-tested in `rust/tests/trace_props.rs`). The bounded
//!   [`Recorder`] ring keeps the newest events and counts drops.
//! - [`export`]: [`export_chrome_trace`] renders the stream as
//!   Chrome-trace JSON (load `trace.json` at `ui.perfetto.dev`);
//!   [`validate_chrome_trace`] is the schema gate CI runs on it.
//! - [`aggregate`]: [`TraceSummary`] reduces the same stream to
//!   per-exit latency distributions, per-buffer stall totals, and
//!   controller reconvergence time (rendered by
//!   `report::tables::render_trace_summary`).
//! - [`diff`]: [`first_divergence`] aligns two pinned-seed streams by
//!   logical track and reports the first event where they disagree
//!   ([`diff_chrome_traces`] does the same over exported Chrome JSON;
//!   CLI: `atheena trace diff A.json B.json`).

pub mod aggregate;
pub mod diff;
pub mod event;
pub mod export;

pub use aggregate::{
    BufferSummary, ControlSummary, DegradationSummary, ExitLatency, TraceSummary,
};
pub use diff::{diff_chrome_traces, first_divergence, Divergence};
pub use event::{NullSink, Recorder, TraceEvent, TraceSink, DEFAULT_RECORDER_CAPACITY};
pub use export::{
    export_chrome_trace, validate_chrome_trace, write_chrome_trace, ChromeTraceStats,
};
