//! Exit-decision arithmetic (paper Eq. 2–4), host-side reference.
//!
//! The authoritative on-"hardware" implementation is the Pallas kernel
//! baked into the stage-1 HLO artifact (python/compile/kernels/
//! exit_decision.py). The coordinator still needs the same math on the
//! host: to re-derive decisions from logits, to sweep thresholds, and to
//! cross-check the artifact's flag (integration tests assert the two
//! agree bit-for-bit on the decision).

/// Numerically-stable softmax (Eq. 3).
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&x| (x - m).exp()).collect();
    let s: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / s).collect()
}

/// Eq. 4 in division-free shifted form:
/// `max_i exp(x_i - m) > C_thr * sum_j exp(x_j - m)`.
/// Both sides of the paper's Eq. 4 scale by `exp(-m)` so the shift
/// preserves the decision exactly while keeping `exp` in range.
pub fn exit_decision(logits: &[f32], c_thr: f64) -> bool {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f64;
    let mut max_e = 0.0f64;
    for &x in logits {
        let e = ((x - m) as f64).exp();
        sum += e;
        max_e = max_e.max(e);
    }
    max_e > c_thr * sum
}

/// Max-softmax confidence (the quantity C_thr thresholds, Eq. 2).
pub fn confidence(logits: &[f32]) -> f64 {
    softmax(logits).iter().copied().fold(0.0f32, f32::max) as f64
}

/// Pick the threshold whose exit rate leaves a fraction `p_target` of
/// samples hard, given per-sample confidences (the calibration step the
/// build-time profiler performs; exposed here so the Rust profiler can
/// re-calibrate against runtime-measured confidences).
pub fn threshold_for_p(confidences: &mut [f64], p_target: f64) -> f64 {
    assert!(!confidences.is_empty());
    confidences.sort_by(|a, b| a.total_cmp(b));
    let idx = ((p_target * confidences.len() as f64) as usize)
        .min(confidences.len() - 1);
    confidences[idx]
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, close, gen_vec, prop_assert};

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn decision_consistent_with_eq2() {
        // Eq. 4 (division-free) must agree with Eq. 2 (max softmax > thr).
        check(300, |r| {
            let n = 2 + r.below(30);
            let logits = gen_vec(r, n, |r| (r.f64() as f32 - 0.5) * 20.0);
            let thr = 0.05 + 0.9 * r.f64();
            let eq4 = exit_decision(&logits, thr);
            let eq2 = confidence(&logits) > thr;
            prop_assert(eq4 == eq2, "Eq.4 and Eq.2 disagree")
        });
    }

    #[test]
    fn decision_shift_invariant() {
        // Adding a constant to all logits must not change the decision
        // (softmax invariance — the stability property the kernel needs).
        check(300, |r| {
            let n = 2 + r.below(10);
            let logits = gen_vec(r, n, |r| (r.f64() as f32 - 0.5) * 8.0);
            let shift = (r.f64() as f32 - 0.5) * 60.0;
            let shifted: Vec<f32> = logits.iter().map(|&x| x + shift).collect();
            let thr = 0.05 + 0.9 * r.f64();
            prop_assert(
                exit_decision(&logits, thr) == exit_decision(&shifted, thr),
                "decision not shift-invariant",
            )
        });
    }

    #[test]
    fn extreme_logits_stay_finite() {
        assert!(exit_decision(&[500.0, -500.0], 0.9));
        assert!(!exit_decision(&[300.0, 300.0], 0.9));
        let p = softmax(&[400.0, -400.0, 0.0]);
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn threshold_calibration_hits_target_p() {
        check(50, |r| {
            let n = 200 + r.below(400);
            let mut conf = gen_vec(r, n, |r| 0.1 + 0.9 * r.f64());
            let p = 0.1 + 0.5 * r.f64();
            let thr = threshold_for_p(&mut conf.clone(), p);
            // Hard = conf <= thr; fraction should be close to p.
            let hard = conf.iter().filter(|&&c| c <= thr).count() as f64 / n as f64;
            conf.sort_by(|a, b| a.total_cmp(b));
            prop_assert(
                close(hard, p, 0.0, 2.0 / n as f64 + 0.02),
                &format!("calibrated hard fraction {hard} vs target {p}"),
            )
        });
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[]), 0);
    }
}
