//! Exit-decision arithmetic (paper Eq. 2–4), host-side reference, plus
//! the runtime operating-point abstractions built on top of it.
//!
//! The authoritative on-"hardware" implementation is the Pallas kernel
//! baked into the stage-1 HLO artifact (python/compile/kernels/
//! exit_decision.py). The coordinator still needs the same math on the
//! host: to re-derive decisions from logits, to sweep thresholds, and to
//! cross-check the artifact's flag (integration tests assert the two
//! agree bit-for-bit on the decision).
//!
//! An [`OperatingPoint`] bundles the per-exit confidence thresholds with
//! the reach vector they are calibrated to induce. A [`ThresholdPolicy`]
//! turns confidences into exit decisions at that operating point:
//! [`Fixed`] applies the thresholds verbatim (bit-identical to the
//! scalar-`c_thr` path the toolflow always used), while [`Controller`]
//! closes the loop — it re-runs the [`threshold_for_p`] calibration over
//! a rolling window of observed confidences so the *realized* exit rates
//! track the design-time reach vector even when the workload difficulty
//! drifts (the §IV p/q-mismatch failure mode, corrected at runtime).

/// Numerically-stable softmax (Eq. 3).
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&x| (x - m).exp()).collect();
    let s: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / s).collect()
}

/// Eq. 4 in division-free shifted form:
/// `max_i exp(x_i - m) > C_thr * sum_j exp(x_j - m)`.
/// Both sides of the paper's Eq. 4 scale by `exp(-m)` so the shift
/// preserves the decision exactly while keeping `exp` in range.
pub fn exit_decision(logits: &[f32], c_thr: f64) -> bool {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f64;
    let mut max_e = 0.0f64;
    for &x in logits {
        let e = ((x - m) as f64).exp();
        sum += e;
        max_e = max_e.max(e);
    }
    max_e > c_thr * sum
}

/// Max-softmax confidence (the quantity C_thr thresholds, Eq. 2).
pub fn confidence(logits: &[f32]) -> f64 {
    softmax(logits).iter().copied().fold(0.0f32, f32::max) as f64
}

/// Pick the threshold whose exit rate leaves a fraction `p_target` of
/// samples hard, given per-sample confidences (the calibration step the
/// build-time profiler performs; exposed here so the Rust profiler and
/// the runtime [`Controller`] can re-calibrate against measured
/// confidences).
///
/// A sample is hard when its confidence is at or below the threshold, so
/// the returned value is the k-th smallest confidence with
/// `k = round(p_target * n)` — the nearest achievable hard count. For
/// `p_target` rounding to zero hard samples the threshold is 0: max-
/// softmax confidences are strictly positive, so nothing lands at or
/// below it.
pub fn threshold_for_p(confidences: &mut [f64], p_target: f64) -> anyhow::Result<f64> {
    anyhow::ensure!(
        !confidences.is_empty(),
        "threshold calibration needs at least one confidence sample"
    );
    anyhow::ensure!(
        (0.0..=1.0).contains(&p_target),
        "target hard probability {p_target} outside [0, 1]"
    );
    confidences.sort_by(|a, b| a.total_cmp(b));
    let k = (p_target * confidences.len() as f64).round() as usize;
    if k == 0 {
        return Ok(0.0);
    }
    Ok(confidences[(k - 1).min(confidences.len() - 1)])
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

// ---------------------------------------------------------------------
// Runtime operating point
// ---------------------------------------------------------------------

/// A runtime operating point: one confidence threshold per exit plus the
/// reach vector those thresholds are calibrated to induce (`reach[i]` =
/// fraction of samples travelling *past* exit `i`). The design-time
/// configuration — every exit at the network's scalar `c_thr`, reach
/// equal to the profiled `reach_profile` — is [`OperatingPoint::uniform`].
#[derive(Clone, Debug, PartialEq)]
pub struct OperatingPoint {
    /// Per-exit max-softmax confidence thresholds (Eq. 2's C_thr).
    pub thresholds: Vec<f64>,
    /// Reach probabilities the thresholds target (non-increasing).
    pub reach: Vec<f64>,
}

impl OperatingPoint {
    /// The design-time point: every exit thresholds at the same `c_thr`,
    /// targeting the profiled reach vector.
    pub fn uniform(c_thr: f64, reach: Vec<f64>) -> OperatingPoint {
        OperatingPoint {
            thresholds: vec![c_thr; reach.len()],
            reach,
        }
    }

    /// Calibrate thresholds for confidences that are Uniform(0, 1) at
    /// nominal difficulty — the synthetic-confidence model the closed-
    /// loop simulator drives policies with. Under that model the
    /// threshold inducing conditional hard probability p is exactly p.
    pub fn for_uniform_confidence(reach: Vec<f64>) -> OperatingPoint {
        let mut op = OperatingPoint {
            thresholds: Vec::new(),
            reach,
        };
        op.thresholds = (0..op.reach.len()).map(|i| op.conditional_p(i)).collect();
        op
    }

    pub fn n_exits(&self) -> usize {
        self.reach.len()
    }

    /// Conditional hard probability at exit `i`: of the samples reaching
    /// exit `i`, the fraction that should travel past it
    /// (`reach[i] / reach[i-1]`, with `reach[-1] = 1`).
    pub fn conditional_p(&self, exit: usize) -> f64 {
        let reached = if exit == 0 { 1.0 } else { self.reach[exit - 1] };
        if reached <= 0.0 {
            0.0
        } else {
            (self.reach[exit] / reached).min(1.0)
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.thresholds.len() == self.reach.len() && !self.reach.is_empty(),
            "operating point needs one threshold per exit"
        );
        anyhow::ensure!(
            self.reach.iter().all(|&r| r > 0.0 && r <= 1.0),
            "operating-point reach probabilities out of range: {:?}",
            self.reach
        );
        anyhow::ensure!(
            self.reach.windows(2).all(|w| w[0] >= w[1]),
            "operating-point reach probabilities must be non-increasing"
        );
        Ok(())
    }
}

/// Exit-decision policy: turns the max-softmax confidence observed at an
/// exit into the take/forward decision, optionally adapting its
/// thresholds from what it observes. Shared by the serving front end and
/// the closed-loop simulator.
pub trait ThresholdPolicy: Send {
    /// Decide whether a sample with max-softmax `confidence` takes exit
    /// `exit`, recording the observation for any adaptive retuning.
    /// Exits are only consulted for samples that actually reach them.
    fn decide(&mut self, exit: usize, confidence: f64) -> bool;

    /// The policy's current operating point (live thresholds).
    fn operating_point(&self) -> &OperatingPoint;

    /// Number of threshold retunes performed so far (0 for fixed
    /// policies).
    fn retunes(&self) -> u64 {
        0
    }

    /// Degradation override hook (DESIGN.md §12): record the
    /// observation exactly as [`ThresholdPolicy::decide`] would — so an
    /// adaptive policy's confidence windows stay faithful to the
    /// traffic — but force the exit to be taken regardless of the
    /// verdict. The serving layer calls this for samples past their
    /// deadline and for admissions shed via
    /// `ShedPolicy::ForceEarlyExit`.
    fn decide_forced(&mut self, exit: usize, confidence: f64) -> bool {
        let _ = self.decide(exit, confidence);
        true
    }
}

/// Fixed thresholds: apply the operating point verbatim. With a uniform
/// operating point at the network's `c_thr` this is bit-identical to the
/// scalar-threshold decision ([`exit_decision`] / the in-graph kernel):
/// the same `confidence > c_thr` comparison, per exit.
#[derive(Clone, Debug)]
pub struct Fixed {
    op: OperatingPoint,
}

impl Fixed {
    pub fn new(op: OperatingPoint) -> Fixed {
        Fixed { op }
    }

    /// The pre-refactor configuration: one scalar `c_thr` for every exit.
    pub fn scalar(c_thr: f64, reach: Vec<f64>) -> Fixed {
        Fixed::new(OperatingPoint::uniform(c_thr, reach))
    }
}

impl ThresholdPolicy for Fixed {
    fn decide(&mut self, exit: usize, confidence: f64) -> bool {
        confidence > self.op.thresholds[exit]
    }

    fn operating_point(&self) -> &OperatingPoint {
        &self.op
    }
}

/// Closed-loop controller: every `window` confidences observed at an
/// exit, re-run the [`threshold_for_p`] calibration over that window for
/// the exit's target conditional hard probability and blend the fresh
/// threshold in. The realized exit-rate vector then tracks the target
/// reach vector under workload drift; at stationary difficulty the
/// thresholds converge to the distribution's true quantiles.
pub struct Controller {
    target: OperatingPoint,
    current: OperatingPoint,
    window: usize,
    /// Weight on the freshly calibrated threshold (1.0 = jump straight
    /// to it; smaller values trade convergence speed for variance).
    blend: f64,
    buf: Vec<Vec<f64>>,
    retunes: u64,
}

impl Controller {
    /// A controller targeting `target`, retuning every `window`
    /// observations per exit with the default 0.5 blend.
    pub fn new(target: OperatingPoint, window: usize) -> Controller {
        Controller::with_blend(target, window, 0.5)
    }

    pub fn with_blend(target: OperatingPoint, window: usize, blend: f64) -> Controller {
        assert!(window >= 8, "controller window too small to calibrate");
        assert!(blend > 0.0 && blend <= 1.0, "blend must be in (0, 1]");
        let n = target.n_exits();
        Controller {
            current: target.clone(),
            target,
            window,
            blend,
            buf: (0..n).map(|_| Vec::new()).collect(),
            retunes: 0,
        }
    }

    /// The operating point this controller steers toward.
    pub fn target(&self) -> &OperatingPoint {
        &self.target
    }
}

impl ThresholdPolicy for Controller {
    fn decide(&mut self, exit: usize, confidence: f64) -> bool {
        let take = confidence > self.current.thresholds[exit];
        let buf = &mut self.buf[exit];
        buf.push(confidence);
        if buf.len() >= self.window {
            let p = self.target.conditional_p(exit);
            if let Ok(thr) = threshold_for_p(buf, p) {
                let old = self.current.thresholds[exit];
                self.current.thresholds[exit] = old + self.blend * (thr - old);
                self.retunes += 1;
            }
            buf.clear();
        }
        take
    }

    fn operating_point(&self) -> &OperatingPoint {
        &self.current
    }

    fn retunes(&self) -> u64 {
        self.retunes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, close, gen_vec, prop_assert};

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn decision_consistent_with_eq2() {
        // Eq. 4 (division-free) must agree with Eq. 2 (max softmax > thr).
        check(300, |r| {
            let n = 2 + r.below(30);
            let logits = gen_vec(r, n, |r| (r.f64() as f32 - 0.5) * 20.0);
            let thr = 0.05 + 0.9 * r.f64();
            let eq4 = exit_decision(&logits, thr);
            let eq2 = confidence(&logits) > thr;
            prop_assert(eq4 == eq2, "Eq.4 and Eq.2 disagree")
        });
    }

    #[test]
    fn decision_shift_invariant() {
        // Adding a constant to all logits must not change the decision
        // (softmax invariance — the stability property the kernel needs).
        check(300, |r| {
            let n = 2 + r.below(10);
            let logits = gen_vec(r, n, |r| (r.f64() as f32 - 0.5) * 8.0);
            let shift = (r.f64() as f32 - 0.5) * 60.0;
            let shifted: Vec<f32> = logits.iter().map(|&x| x + shift).collect();
            let thr = 0.05 + 0.9 * r.f64();
            prop_assert(
                exit_decision(&logits, thr) == exit_decision(&shifted, thr),
                "decision not shift-invariant",
            )
        });
    }

    #[test]
    fn extreme_logits_stay_finite() {
        assert!(exit_decision(&[500.0, -500.0], 0.9));
        assert!(!exit_decision(&[300.0, 300.0], 0.9));
        let p = softmax(&[400.0, -400.0, 0.0]);
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn threshold_calibration_hits_target_p() {
        check(50, |r| {
            let n = 200 + r.below(400);
            let conf = gen_vec(r, n, |r| 0.1 + 0.9 * r.f64());
            let p = 0.1 + 0.5 * r.f64();
            let thr = threshold_for_p(&mut conf.clone(), p).unwrap();
            // Hard = conf <= thr; fraction should be close to p.
            let hard = conf.iter().filter(|&&c| c <= thr).count() as f64 / n as f64;
            prop_assert(
                close(hard, p, 0.0, 2.0 / n as f64 + 0.02),
                &format!("calibrated hard fraction {hard} vs target {p}"),
            )
        });
    }

    #[test]
    fn threshold_calibration_edge_cases() {
        // Empty input: an error, not a panic.
        assert!(threshold_for_p(&mut [], 0.5).is_err());
        // Out-of-range targets rejected.
        assert!(threshold_for_p(&mut [0.5], -0.1).is_err());
        assert!(threshold_for_p(&mut [0.5], 1.1).is_err());
        // Single element: p = 1 keeps it hard, p = 0 exits it.
        assert_eq!(threshold_for_p(&mut [0.7], 1.0).unwrap(), 0.7);
        assert_eq!(threshold_for_p(&mut [0.7], 0.0).unwrap(), 0.0);
        // p = 0 leaves nothing at or below the threshold; p = 1 leaves
        // everything (confidences are strictly positive).
        let conf = vec![0.2, 0.9, 0.4, 0.6];
        let t0 = threshold_for_p(&mut conf.clone(), 0.0).unwrap();
        assert_eq!(conf.iter().filter(|&&c| c <= t0).count(), 0);
        let t1 = threshold_for_p(&mut conf.clone(), 1.0).unwrap();
        assert_eq!(conf.iter().filter(|&&c| c <= t1).count(), conf.len());
        // Quantile rounding: nearest achievable hard count, not floor.
        // n = 4, p = 0.4 -> round(1.6) = 2 hard samples.
        let t = threshold_for_p(&mut conf.clone(), 0.4).unwrap();
        assert_eq!(conf.iter().filter(|&&c| c <= t).count(), 2);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn operating_point_conditional_probabilities() {
        let op = OperatingPoint::uniform(0.9, vec![0.4, 0.1]);
        op.validate().unwrap();
        assert_eq!(op.n_exits(), 2);
        assert_eq!(op.thresholds, vec![0.9, 0.9]);
        assert!((op.conditional_p(0) - 0.4).abs() < 1e-12);
        assert!((op.conditional_p(1) - 0.25).abs() < 1e-12);

        // The uniform-confidence calibration: thresholds equal the
        // conditional hard probabilities.
        let cal = OperatingPoint::for_uniform_confidence(vec![0.4, 0.1]);
        assert!((cal.thresholds[0] - 0.4).abs() < 1e-12);
        assert!((cal.thresholds[1] - 0.25).abs() < 1e-12);

        // Malformed points rejected.
        assert!(OperatingPoint::uniform(0.9, vec![]).validate().is_err());
        assert!(OperatingPoint::uniform(0.9, vec![0.1, 0.4]).validate().is_err());
        assert!(OperatingPoint::uniform(0.9, vec![0.4, 0.0]).validate().is_err());
    }

    #[test]
    fn fixed_policy_matches_scalar_exit_decision() {
        // The Fixed policy at a uniform operating point is bit-identical
        // to the scalar-c_thr decision on the same confidences, at every
        // exit.
        check(300, |r| {
            let n = 2 + r.below(20);
            let logits = gen_vec(r, n, |r| (r.f64() as f32 - 0.5) * 16.0);
            let thr = 0.05 + 0.9 * r.f64();
            let mut fixed = Fixed::scalar(thr, vec![0.4, 0.2, 0.1]);
            let conf = confidence(&logits);
            for exit in 0..3 {
                prop_assert(
                    fixed.decide(exit, conf) == exit_decision(&logits, thr),
                    "Fixed policy diverged from the scalar decision",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn controller_converges_to_distribution_quantile() {
        // Stationary Uniform(0,1) confidences: the controller's
        // threshold must settle near the target conditional quantile and
        // the realized exit rate near the target.
        let target = OperatingPoint::for_uniform_confidence(vec![0.3]);
        let mut ctl = Controller::new(target.clone(), 512);
        let mut rng = crate::util::Rng::new(0xC0117);
        let mut hard_tail = 0usize;
        let tail_start = 16 * 512;
        let total = 24 * 512;
        for s in 0..total {
            let conf = rng.f64();
            let take = ctl.decide(0, conf);
            if s >= tail_start && !take {
                hard_tail += 1;
            }
        }
        assert!(ctl.retunes() >= 16);
        let thr = ctl.operating_point().thresholds[0];
        assert!((thr - 0.3).abs() < 0.05, "threshold {thr} far from 0.3");
        let rate = hard_tail as f64 / (total - tail_start) as f64;
        assert!((rate - 0.3).abs() < 0.05, "hard rate {rate} far from 0.3");
    }

    #[test]
    fn controller_tracks_a_difficulty_shift() {
        // After confidences compress (conf -> conf^2, harder), a fixed
        // threshold over-selects hard samples; the controller retunes
        // back to the target rate.
        let target = OperatingPoint::for_uniform_confidence(vec![0.25]);
        let mut fixed = Fixed::new(target.clone());
        let mut ctl = Controller::new(target.clone(), 512);
        let mut rng = crate::util::Rng::new(0x5417F);
        let (mut hard_fixed, mut hard_ctl, mut tail) = (0usize, 0usize, 0usize);
        let total = 24 * 512;
        for s in 0..total {
            let conf = rng.f64().powi(2);
            let take_f = fixed.decide(0, conf);
            let take_c = ctl.decide(0, conf);
            if s >= total / 2 {
                tail += 1;
                if !take_f {
                    hard_fixed += 1;
                }
                if !take_c {
                    hard_ctl += 1;
                }
            }
        }
        let rate_fixed = hard_fixed as f64 / tail as f64;
        let rate_ctl = hard_ctl as f64 / tail as f64;
        // Fixed drifts to sqrt(0.25) = 0.5 hard; the controller holds
        // the design rate.
        assert!((rate_fixed - 0.5).abs() < 0.05, "fixed rate {rate_fixed}");
        assert!((rate_ctl - 0.25).abs() < 0.04, "controlled rate {rate_ctl}");
    }
}
