//! Early-Exit specifics: the exit-decision math (Eq. 2–4) and the
//! Early-Exit profiler (§III-B.1).

pub mod decision;
pub mod profiler;

pub use decision::{exit_decision, softmax, threshold_for_p};
pub use profiler::{ExitOracle, ProfileReport, Profiler};
