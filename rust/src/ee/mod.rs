//! Early-Exit specifics: the exit-decision math (Eq. 2–4), the
//! Early-Exit profiler (§III-B.1), and the runtime operating-point
//! machinery (thresholds-as-signals: [`OperatingPoint`],
//! [`ThresholdPolicy`], the streaming [`ReachEstimator`]).

pub mod decision;
pub mod profiler;

pub use decision::{
    exit_decision, softmax, threshold_for_p, Controller, Fixed, OperatingPoint,
    ThresholdPolicy,
};
pub use profiler::{ExitOracle, ProfileReport, Profiler, ReachEstimator};
