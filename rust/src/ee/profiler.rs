//! The Early-Exit profiler (§III-B.1), N-exit form.
//!
//! "We introduce the Early-Exit profiler which takes a profiling data set
//! and the high-level Early-Exit ConvNet description and apportions the
//! set so that multiple distinct tests can be run which will have a
//! similar probability of hard samples on average but variation
//! individually. Batched inference is performed over the sets followed by
//! collection of the exit probabilities, exit accuracy, and cumulative
//! accuracy. The average probability of hard samples is fed into the
//! optimizer as p."
//!
//! For an N-exit network the profiler measures the whole **reach
//! vector**: `reach[i]` is the fraction of samples travelling past exit
//! `i`, which the optimizer consumes via `tap::combine_multi`. The
//! two-stage `p` is `reach[0]`.
//!
//! The inference backend is abstracted as [`ExitOracle`] so the profiler
//! is testable without artifacts; the production implementation runs the
//! per-stage HLO executables over PJRT (`coordinator::batch`).
//!
//! The batch profiler measures the reach vector *offline*; its streaming
//! sibling [`ReachEstimator`] measures the same vector *online*, one
//! completed sample at a time, and is shared by the serving front end
//! and the closed-loop simulator as the observation half of the
//! operating-point control loop (estimator → policy → thresholds →
//! realized reach).

use crate::data::TestSet;

/// Per-sample inference outcome needed by the profiler.
#[derive(Clone, Copy, Debug)]
pub struct ExitOutcome {
    /// Early exit taken: `Some(i)` means the sample completed at exit
    /// `i`; `None` means it ran through to the final classifier.
    pub exit: Option<usize>,
    /// Prediction of the classifier the sample completed at.
    pub pred: usize,
}

/// Inference backend over which profiling runs.
pub trait ExitOracle {
    fn run(&mut self, images: &[&[f32]]) -> anyhow::Result<Vec<ExitOutcome>>;
}

/// One profiling split's statistics.
#[derive(Clone, Debug, Default)]
pub struct SplitStats {
    pub n: usize,
    /// Fraction of the split travelling past each exit.
    pub reach: Vec<f64>,
    /// Fraction of the split that was hard at the first exit
    /// (`reach[0]`; the two-stage p).
    pub p_hard: f64,
    pub exit_acc_on_taken: f64,
    pub deployed_acc: f64,
}

/// Aggregated profiler output: the reach vector fed to the optimizer +
/// accuracies.
#[derive(Clone, Debug, Default)]
pub struct ProfileReport {
    pub splits: Vec<SplitStats>,
    /// Average reach probability past each exit across splits (the
    /// optimizer's reach vector).
    pub reach: Vec<f64>,
    /// `reach[0]` — the two-stage p fed to the optimizer.
    pub p_hard: f64,
    /// Standard deviation of `reach[0]` across splits (the q-variation
    /// the design must be robust to — drives the buffer margin).
    pub p_std: f64,
    pub exit_acc_on_taken: f64,
    pub deployed_acc: f64,
}

pub struct Profiler {
    /// Number of distinct splits ("multiple distinct tests ... similar
    /// probability on average but variation individually").
    pub splits: usize,
}

impl Default for Profiler {
    fn default() -> Self {
        Profiler { splits: 4 }
    }
}

impl Profiler {
    /// Profile a test set through an oracle for a network with
    /// `n_exits` early exits.
    ///
    /// §Perf: oracle inference is inherently serial (the backend is
    /// stateful and `&mut`), but the per-split reach/accuracy statistics
    /// are pure functions of each split's outcomes — they run on the
    /// deterministic executor after all inference completes, in split
    /// order, so the report is bit-identical to the fused serial loop.
    pub fn profile(
        &self,
        oracle: &mut dyn ExitOracle,
        ts: &TestSet,
        samples: usize,
        n_exits: usize,
    ) -> anyhow::Result<ProfileReport> {
        let n = samples.min(ts.n);
        anyhow::ensure!(n >= self.splits, "need at least one sample per split");
        anyhow::ensure!(n_exits >= 1, "network must have at least one exit");
        let per = n / self.splits;
        let mut report = ProfileReport::default();

        // Pass 1 (serial): batched inference per split.
        let mut ranges = Vec::with_capacity(self.splits);
        let mut split_outcomes = Vec::with_capacity(self.splits);
        for split in 0..self.splits {
            let lo = split * per;
            let hi = if split + 1 == self.splits { n } else { lo + per };
            let images: Vec<&[f32]> = (lo..hi).map(|i| ts.image(i)).collect();
            let outcomes = oracle.run(&images)?;
            anyhow::ensure!(outcomes.len() == hi - lo, "oracle returned wrong count");
            ranges.push((lo, hi));
            split_outcomes.push(outcomes);
        }

        // Pass 2 (parallel): reach-vector + accuracy measurement.
        let stats = crate::util::exec::run_ordered(self.splits, |split| {
            let (lo, hi) = ranges[split];
            split_stats(&split_outcomes[split], &ts.labels[lo..hi], n_exits)
        });
        for s in stats {
            report.splits.push(s?);
        }
        // Aggregate reach vector (split-weighted means).
        report.reach = (0..n_exits)
            .map(|e| {
                report
                    .splits
                    .iter()
                    .map(|s| s.reach[e] * s.n as f64)
                    .sum::<f64>()
                    / n as f64
            })
            .collect();
        // Contract: p_hard IS reach[0] (both sample-weighted); p_std
        // measures the split-to-split spread around it.
        report.p_hard = report.reach[0];
        let ps: Vec<f64> = report.splits.iter().map(|s| s.p_hard).collect();
        report.p_std = (ps
            .iter()
            .map(|p| (p - report.p_hard).powi(2))
            .sum::<f64>()
            / ps.len() as f64)
            .sqrt();
        report.exit_acc_on_taken = report
            .splits
            .iter()
            .map(|s| s.exit_acc_on_taken * s.n as f64)
            .sum::<f64>()
            / n as f64;
        report.deployed_acc = report
            .splits
            .iter()
            .map(|s| s.deployed_acc * s.n as f64)
            .sum::<f64>()
            / n as f64;
        Ok(report)
    }
}

/// One split's reach-vector + accuracy statistics from its inference
/// outcomes (`labels[k]` corresponds to `outcomes[k]`). Pure — safe to
/// evaluate for every split in parallel.
fn split_stats(
    outcomes: &[ExitOutcome],
    labels: &[u8],
    n_exits: usize,
) -> anyhow::Result<SplitStats> {
    let mut past = vec![0usize; n_exits];
    let mut taken_correct = 0usize;
    let mut taken = 0usize;
    let mut deployed_correct = 0usize;
    for (k, o) in outcomes.iter().enumerate() {
        let label = labels[k] as usize;
        // A sample completing at exit e (or the final classifier,
        // e = n_exits) travelled past exits 0..e.
        let depth = match o.exit {
            Some(e) => {
                anyhow::ensure!(e < n_exits, "oracle reported exit {e} of {n_exits}");
                taken += 1;
                if o.pred == label {
                    taken_correct += 1;
                }
                e
            }
            None => n_exits,
        };
        for p in past.iter_mut().take(depth) {
            *p += 1;
        }
        if o.pred == label {
            deployed_correct += 1;
        }
    }
    let m = outcomes.len();
    Ok(SplitStats {
        n: m,
        reach: past.iter().map(|&c| c as f64 / m as f64).collect(),
        p_hard: past[0] as f64 / m as f64,
        exit_acc_on_taken: if taken > 0 {
            taken_correct as f64 / taken as f64
        } else {
            0.0
        },
        deployed_acc: deployed_correct as f64 / m as f64,
    })
}

// ---------------------------------------------------------------------
// Streaming reach estimation
// ---------------------------------------------------------------------

/// Streaming estimator of the realized reach vector.
///
/// Each completed sample reports its completion *depth* — the pipeline
/// section it completed at, which equals the number of exits it
/// travelled past (exit index for early exits, `n_exits` for the final
/// classifier; the same convention as `SampleTrace::exit_stage` and
/// `Response::exit_stage`). The estimator maintains
///
/// * an EWMA estimate per exit (`alpha = 2 / (window + 1)`), the live
///   signal a controller or operator watches, and
/// * exact per-window counts, rolled every `window` samples, for
///   reporting realized rates over a bounded horizon.
#[derive(Clone, Debug)]
pub struct ReachEstimator {
    n_exits: usize,
    alpha: f64,
    window: usize,
    n: u64,
    ewma: Vec<f64>,
    win_past: Vec<u64>,
    win_n: usize,
    last_window: Option<Vec<f64>>,
}

impl ReachEstimator {
    /// An estimator over `window` samples (EWMA alpha = 2/(window+1)).
    pub fn windowed(n_exits: usize, window: usize) -> ReachEstimator {
        let window = window.max(1);
        ReachEstimator {
            n_exits,
            alpha: 2.0 / (window as f64 + 1.0),
            window,
            n: 0,
            ewma: vec![0.0; n_exits],
            win_past: vec![0; n_exits],
            win_n: 0,
            last_window: None,
        }
    }

    /// Record one completed sample at completion depth `depth` (exits
    /// travelled past; values beyond `n_exits` count as the final
    /// classifier).
    pub fn observe(&mut self, depth: usize) {
        let first = self.n == 0;
        for i in 0..self.n_exits {
            let ind = if depth > i { 1.0 } else { 0.0 };
            if first {
                self.ewma[i] = ind;
            } else {
                self.ewma[i] += self.alpha * (ind - self.ewma[i]);
            }
            if depth > i {
                self.win_past[i] += 1;
            }
        }
        self.n += 1;
        self.win_n += 1;
        if self.win_n >= self.window {
            self.last_window = Some(
                self.win_past
                    .iter()
                    .map(|&c| c as f64 / self.win_n as f64)
                    .collect(),
            );
            self.win_past.iter_mut().for_each(|c| *c = 0);
            self.win_n = 0;
        }
    }

    /// Samples observed so far.
    pub fn samples(&self) -> u64 {
        self.n
    }

    /// The EWMA reach estimate (fraction past each exit).
    pub fn reach(&self) -> &[f64] {
        &self.ewma
    }

    /// Exact reach over the last *completed* window, if one has rolled.
    pub fn window_reach(&self) -> Option<&[f64]> {
        self.last_window.as_deref()
    }

    /// Largest absolute EWMA deviation from a target reach vector — the
    /// drift signal an operator alarms on. Extra target entries are
    /// ignored; a missing estimate counts as full deviation.
    pub fn max_deviation(&self, target: &[f64]) -> f64 {
        target
            .iter()
            .enumerate()
            .map(|(i, &t)| match self.ewma.get(i) {
                Some(&e) => (e - t).abs(),
                None => t.abs(),
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic_testset;

    /// Mock oracle: uses the set's ground-truth flags and is always right
    /// on easy samples, 80% right on hard ones.
    struct MockOracle<'a> {
        ts: &'a TestSet,
        cursor: usize,
    }

    impl ExitOracle for MockOracle<'_> {
        fn run(&mut self, images: &[&[f32]]) -> anyhow::Result<Vec<ExitOutcome>> {
            let mut out = Vec::new();
            for _ in images {
                let i = self.cursor;
                self.cursor += 1;
                let label = self.ts.labels[i] as usize;
                let hard = self.ts.hard[i] != 0;
                out.push(ExitOutcome {
                    exit: if hard { None } else { Some(0) },
                    pred: if hard && i % 5 == 0 {
                        (label + 1) % 10
                    } else {
                        label
                    },
                });
            }
            Ok(out)
        }
    }

    /// A three-exit mock: routes sample i past exit 0 when hard, and of
    /// those, every other one past exit 1 as well.
    struct MockDeepOracle<'a> {
        ts: &'a TestSet,
        cursor: usize,
    }

    impl ExitOracle for MockDeepOracle<'_> {
        fn run(&mut self, images: &[&[f32]]) -> anyhow::Result<Vec<ExitOutcome>> {
            let mut out = Vec::new();
            for _ in images {
                let i = self.cursor;
                self.cursor += 1;
                let label = self.ts.labels[i] as usize;
                let hard = self.ts.hard[i] != 0;
                let exit = if !hard {
                    Some(0)
                } else if i % 2 == 0 {
                    Some(1)
                } else {
                    None
                };
                out.push(ExitOutcome { exit, pred: label });
            }
            Ok(out)
        }
    }

    #[test]
    fn profiler_recovers_p_and_accuracy() {
        let ts = synthetic_testset(2000, 4, 0.25, 9);
        let mut oracle = MockOracle { ts: &ts, cursor: 0 };
        let report = Profiler::default()
            .profile(&mut oracle, &ts, 2000, 1)
            .unwrap();
        assert_eq!(report.splits.len(), 4);
        assert!(
            (report.p_hard - ts.hard_fraction()).abs() < 0.01,
            "p {} vs {}",
            report.p_hard,
            ts.hard_fraction()
        );
        assert_eq!(report.reach.len(), 1);
        assert!((report.reach[0] - report.p_hard).abs() < 1e-9);
        assert!((report.exit_acc_on_taken - 1.0).abs() < 1e-9);
        assert!(report.deployed_acc > 0.9);
        assert!(report.p_std < 0.1, "splits should be similar");
    }

    #[test]
    fn profiler_measures_full_reach_vector() {
        let ts = synthetic_testset(2000, 4, 0.4, 5);
        let mut oracle = MockDeepOracle { ts: &ts, cursor: 0 };
        let report = Profiler::default()
            .profile(&mut oracle, &ts, 2000, 2)
            .unwrap();
        assert_eq!(report.reach.len(), 2);
        // reach[0] ~ hard fraction; reach[1] ~ half of it.
        assert!((report.reach[0] - ts.hard_fraction()).abs() < 0.02);
        assert!((report.reach[1] - ts.hard_fraction() / 2.0).abs() < 0.03);
        // Reach must be non-increasing.
        assert!(report.reach[0] >= report.reach[1]);
    }

    #[test]
    fn p_hard_is_reach0_even_with_uneven_splits() {
        // 2001 samples over 4 splits (500/500/500/501): the weighted
        // reach mean and p_hard must still agree exactly.
        let ts = synthetic_testset(2001, 4, 0.3, 11);
        let mut oracle = MockOracle { ts: &ts, cursor: 0 };
        let report = Profiler::default()
            .profile(&mut oracle, &ts, 2001, 1)
            .unwrap();
        assert_eq!(report.p_hard.to_bits(), report.reach[0].to_bits());
    }

    #[test]
    fn too_few_samples_rejected() {
        let ts = synthetic_testset(3, 4, 0.5, 1);
        let mut oracle = MockOracle { ts: &ts, cursor: 0 };
        assert!(Profiler::default().profile(&mut oracle, &ts, 3, 1).is_err());
    }

    #[test]
    fn estimator_tracks_stationary_reach() {
        // Depth stream with exact rates: 40% past exit 0, 10% past
        // exit 1 (depth 0/1/2 in proportions 60/30/10).
        let mut est = ReachEstimator::windowed(2, 100);
        for i in 0..2000 {
            let depth = match i % 10 {
                0..=5 => 0,
                6..=8 => 1,
                _ => 2,
            };
            est.observe(depth);
        }
        assert_eq!(est.samples(), 2000);
        let r = est.reach();
        assert!((r[0] - 0.4).abs() < 0.05, "reach0 {}", r[0]);
        assert!((r[1] - 0.1).abs() < 0.05, "reach1 {}", r[1]);
        // Completed windows report the exact rates.
        let w = est.window_reach().expect("window rolled");
        assert!((w[0] - 0.4).abs() < 1e-9);
        assert!((w[1] - 0.1).abs() < 1e-9);
        assert!(est.max_deviation(&[0.4, 0.1]) < 0.05);
    }

    #[test]
    fn estimator_reacts_to_a_rate_shift() {
        let mut est = ReachEstimator::windowed(1, 64);
        for _ in 0..640 {
            est.observe(0); // nobody travels past the exit
        }
        assert!(est.reach()[0] < 0.01);
        for _ in 0..640 {
            est.observe(1); // everybody does
        }
        assert!(est.reach()[0] > 0.99);
        assert!((est.window_reach().unwrap()[0] - 1.0).abs() < 1e-9);
        assert!(est.max_deviation(&[0.5]) > 0.45);
    }
}
