//! The Early-Exit profiler (§III-B.1).
//!
//! "We introduce the Early-Exit profiler which takes a profiling data set
//! and the high-level Early-Exit ConvNet description and apportions the
//! set so that multiple distinct tests can be run which will have a
//! similar probability of hard samples on average but variation
//! individually. Batched inference is performed over the sets followed by
//! collection of the exit probabilities, exit accuracy, and cumulative
//! accuracy. The average probability of hard samples is fed into the
//! optimizer as p."
//!
//! The inference backend is abstracted as [`ExitOracle`] so the profiler
//! is testable without artifacts; the production implementation runs the
//! stage-1/stage-2 HLO executables over PJRT (`coordinator::batch`).

use crate::data::TestSet;

/// Per-sample inference outcome needed by the profiler.
#[derive(Clone, Copy, Debug)]
pub struct ExitOutcome {
    /// Did the exit decision fire (sample exits early)?
    pub take_exit: bool,
    /// Early-exit classifier prediction.
    pub pred_exit: usize,
    /// Final classifier prediction (None if the backend short-circuits
    /// stage 2 for exited samples — the profiler then uses pred_exit).
    pub pred_final: Option<usize>,
}

/// Inference backend over which profiling runs.
pub trait ExitOracle {
    fn run(&mut self, images: &[&[f32]]) -> anyhow::Result<Vec<ExitOutcome>>;
}

/// One profiling split's statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct SplitStats {
    pub n: usize,
    pub p_hard: f64,
    pub exit_acc_on_taken: f64,
    pub deployed_acc: f64,
}

/// Aggregated profiler output: the p fed to the optimizer + accuracies.
#[derive(Clone, Debug, Default)]
pub struct ProfileReport {
    pub splits: Vec<SplitStats>,
    /// Average hard-sample probability across splits (the optimizer's p).
    pub p_hard: f64,
    /// Standard deviation of p across splits (the q-variation the design
    /// must be robust to — drives the buffer margin).
    pub p_std: f64,
    pub exit_acc_on_taken: f64,
    pub deployed_acc: f64,
}

pub struct Profiler {
    /// Number of distinct splits ("multiple distinct tests ... similar
    /// probability on average but variation individually").
    pub splits: usize,
}

impl Default for Profiler {
    fn default() -> Self {
        Profiler { splits: 4 }
    }
}

impl Profiler {
    /// Profile a test set through an oracle.
    pub fn profile(
        &self,
        oracle: &mut dyn ExitOracle,
        ts: &TestSet,
        samples: usize,
    ) -> anyhow::Result<ProfileReport> {
        let n = samples.min(ts.n);
        anyhow::ensure!(n >= self.splits, "need at least one sample per split");
        let per = n / self.splits;
        let mut report = ProfileReport::default();
        for split in 0..self.splits {
            let lo = split * per;
            let hi = if split + 1 == self.splits { n } else { lo + per };
            let images: Vec<&[f32]> = (lo..hi).map(|i| ts.image(i)).collect();
            let outcomes = oracle.run(&images)?;
            anyhow::ensure!(outcomes.len() == hi - lo, "oracle returned wrong count");
            let mut hard = 0usize;
            let mut taken_correct = 0usize;
            let mut taken = 0usize;
            let mut deployed_correct = 0usize;
            for (k, o) in outcomes.iter().enumerate() {
                let label = ts.labels[lo + k] as usize;
                if o.take_exit {
                    taken += 1;
                    if o.pred_exit == label {
                        taken_correct += 1;
                        deployed_correct += 1;
                    }
                } else {
                    hard += 1;
                    let pred = o.pred_final.unwrap_or(o.pred_exit);
                    if pred == label {
                        deployed_correct += 1;
                    }
                }
            }
            let m = hi - lo;
            report.splits.push(SplitStats {
                n: m,
                p_hard: hard as f64 / m as f64,
                exit_acc_on_taken: if taken > 0 {
                    taken_correct as f64 / taken as f64
                } else {
                    0.0
                },
                deployed_acc: deployed_correct as f64 / m as f64,
            });
        }
        let ps: Vec<f64> = report.splits.iter().map(|s| s.p_hard).collect();
        report.p_hard = ps.iter().sum::<f64>() / ps.len() as f64;
        report.p_std = (ps
            .iter()
            .map(|p| (p - report.p_hard).powi(2))
            .sum::<f64>()
            / ps.len() as f64)
            .sqrt();
        report.exit_acc_on_taken = report
            .splits
            .iter()
            .map(|s| s.exit_acc_on_taken * s.n as f64)
            .sum::<f64>()
            / n as f64;
        report.deployed_acc = report
            .splits
            .iter()
            .map(|s| s.deployed_acc * s.n as f64)
            .sum::<f64>()
            / n as f64;
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic_testset;

    /// Mock oracle: uses the set's ground-truth flags and is always right
    /// on easy samples, 80% right on hard ones.
    struct MockOracle<'a> {
        ts: &'a TestSet,
        cursor: usize,
    }

    impl ExitOracle for MockOracle<'_> {
        fn run(&mut self, images: &[&[f32]]) -> anyhow::Result<Vec<ExitOutcome>> {
            let mut out = Vec::new();
            for _ in images {
                let i = self.cursor;
                self.cursor += 1;
                let label = self.ts.labels[i] as usize;
                let hard = self.ts.hard[i] != 0;
                out.push(ExitOutcome {
                    take_exit: !hard,
                    pred_exit: label,
                    pred_final: Some(if i % 5 == 0 { (label + 1) % 10 } else { label }),
                });
            }
            Ok(out)
        }
    }

    #[test]
    fn profiler_recovers_p_and_accuracy() {
        let ts = synthetic_testset(2000, 4, 0.25, 9);
        let mut oracle = MockOracle { ts: &ts, cursor: 0 };
        let report = Profiler::default()
            .profile(&mut oracle, &ts, 2000)
            .unwrap();
        assert_eq!(report.splits.len(), 4);
        assert!(
            (report.p_hard - ts.hard_fraction()).abs() < 0.01,
            "p {} vs {}",
            report.p_hard,
            ts.hard_fraction()
        );
        assert!((report.exit_acc_on_taken - 1.0).abs() < 1e-9);
        assert!(report.deployed_acc > 0.9);
        assert!(report.p_std < 0.1, "splits should be similar");
    }

    #[test]
    fn too_few_samples_rejected() {
        let ts = synthetic_testset(3, 4, 0.5, 1);
        let mut oracle = MockOracle { ts: &ts, cursor: 0 };
        assert!(Profiler::default().profile(&mut oracle, &ts, 3).is_err());
    }
}
