//! PJRT runtime benchmarks — per-sample numerics latency on the request
//! path (Table III's host-side column): stage-1, stage-2, baseline, and
//! the full easy/hard sample paths.
//!
//! Requires `make artifacts`. Skips gracefully when artifacts are absent
//! so `cargo bench` stays green in a fresh checkout.
//!
//!     cargo bench --bench bench_runtime

use atheena::data::TestSet;
use atheena::runtime::ArtifactStore;
use atheena::util::bench::bench;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("networks/blenet.json").exists() {
        println!("bench_runtime: artifacts missing, skipping (run `make artifacts`)");
        return Ok(());
    }
    let store = ArtifactStore::open(artifacts)?;

    for name in store.network_names() {
        let ts = TestSet::load(artifacts, &name)?;
        let s1 = store.stage1(&name)?;
        let s2 = store.stage2(&name)?;
        let base = store.baseline(&name)?;

        // A known-easy and known-hard sample for path-specific latency.
        let easy_idx = (0..ts.n).find(|&i| ts.hard[i] == 0).unwrap_or(0);
        let hard_idx = (0..ts.n).find(|&i| ts.hard[i] != 0).unwrap_or(0);

        let s = bench(&format!("pjrt/{name}/stage1"), 5, 50, || {
            s1.run(ts.image(easy_idx)).unwrap()
        });
        println!("  -> {:.0} stage1 samples/s", s.per_second());

        let features = s1.run(ts.image(hard_idx))?.features;
        bench(&format!("pjrt/{name}/stage2"), 5, 50, || {
            s2.run(&features).unwrap()
        });
        bench(&format!("pjrt/{name}/baseline"), 5, 50, || {
            base.run(ts.image(easy_idx)).unwrap()
        });

        // Full request paths (what the serving router pays per sample).
        bench(&format!("pjrt/{name}/path-easy"), 5, 50, || {
            let o = s1.run(ts.image(easy_idx)).unwrap();
            assert!(o.take_exit);
            o.pred()
        });
        bench(&format!("pjrt/{name}/path-hard"), 5, 50, || {
            let o = s1.run(ts.image(hard_idx)).unwrap();
            assert!(!o.take_exit);
            s2.run(&o.features).unwrap()
        });
    }
    Ok(())
}
