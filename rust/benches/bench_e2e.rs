//! End-to-end benchmarks — the Table IV generator: full toolflow wall
//! time per network/board via the staged pipeline (per-stage timings +
//! parallel-vs-sequential sweep), plus the batched-host run of Table III.
//!
//! Uses exported artifacts when present, else the built-in test network.
//!
//!     cargo bench --bench bench_e2e [-- --quick] [-- --save-json]
//!
//! `--quick` runs the quick DSE schedule only (the CI smoke
//! configuration); `--save-json` writes `BENCH_e2e.json` so the perf
//! trajectory is tracked run over run.

use atheena::coordinator::pipeline::Toolflow;
use atheena::coordinator::toolflow::{run_toolflow, ToolflowOptions};
use atheena::ir::network::testnet;
use atheena::ir::Network;
use atheena::resources::Board;
use atheena::util::bench::BenchLog;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let save = args.iter().any(|a| a == "--save-json");
    let mut log = BenchLog::new();
    let artifacts = std::path::Path::new("artifacts");

    // Toolflow wall time on the built-in network (no artifacts needed).
    let net = testnet::blenet_like();
    log.once("toolflow/testnet/quick-schedule", || {
        run_toolflow(&net, &ToolflowOptions::quick(Board::zc706()), None).unwrap()
    });
    if !quick {
        log.once("toolflow/testnet/full-schedule", || {
            run_toolflow(&net, &ToolflowOptions::new(Board::zc706()), None).unwrap()
        });
    }

    // Staged breakdown: where the wall time goes, and what the scoped-
    // thread sweep buys over the sequential reference path.
    let opts = if quick {
        ToolflowOptions::quick(Board::zc706())
    } else {
        ToolflowOptions::new(Board::zc706())
    };
    log.once("pipeline/testnet/sweep-parallel", || {
        Toolflow::new(&net, &opts).unwrap().sweep().unwrap()
    });
    log.once("pipeline/testnet/sweep-sequential", || {
        Toolflow::new(&net, &opts)
            .unwrap()
            .sweep_sequential()
            .unwrap()
    });
    let (realized, _) = log.once("pipeline/testnet/combine+realize", || {
        Toolflow::new(&net, &opts)
            .unwrap()
            .sweep()
            .unwrap()
            .combine()
            .unwrap()
            .realize()
            .unwrap()
    });
    log.once("pipeline/testnet/measure", || realized.measure(None).unwrap());

    if quick || !artifacts.join("networks/blenet.json").exists() {
        if !quick {
            println!("bench_e2e: artifacts missing, exported-network benches skipped");
        }
        if save {
            log.save("BENCH_e2e.json")?;
        }
        return Ok(());
    }

    // Table IV regeneration cost: full toolflow per (network, board).
    for (name, board) in [
        ("blenet", Board::zc706()),
        ("triplewins", Board::vu440()),
        ("balexnet", Board::vu440()),
    ] {
        let net = Network::from_file(
            &artifacts.join("networks").join(format!("{name}.json")),
        )?;
        let (r, _) = log.once(&format!("toolflow/{name}/{}", board.name), || {
            run_toolflow(&net, &ToolflowOptions::new(board.clone()), None).unwrap()
        });
        let best = r.best_design().unwrap();
        println!(
            "  -> {} designs, best predicted {:.0} samples/s at p={:.2}",
            r.designs.len(),
            best.combined.throughput_at_design,
            r.p()
        );
    }
    if save {
        log.save("BENCH_e2e.json")?;
    }
    Ok(())
}
