//! Hot-path benchmarks — the three loops this repo's search cost lives
//! in, measured as higher-is-better throughput metrics and persisted to
//! the perf-trajectory JSONs:
//!
//! * `simulate_multi` samples/s (fresh-allocation vs reused
//!   [`SimScratch`] vs the compiled kernel — `compiled-b{batch}`,
//!   target ≥5× scratch — plus traced-vs-untraced: NullSink and live
//!   Recorder entries)                    → `BENCH_sim.json`
//! * simulated-annealing proposals/s (parallel restarts vs the
//!   sequential reference)                → `BENCH_dse.json`
//! * cold `run_toolflow` wall-clock on the 3-exit test network
//!                                        → `BENCH_e2e.json`
//!
//!     cargo bench --bench bench_hotpath [-- --quick] [-- --save-json] [-- --check]
//!
//! `--check` compares this run's metrics against the committed
//! `BENCH_*.json` baselines (25% tolerance; shared keys only) and fails
//! on regression. The binary always verifies the warm-cache contract —
//! a warm design store measuring with a nonzero anneal-call delta is a
//! hard error — so CI fails if either gate breaks.

use atheena::coordinator::pipeline::Realized;
use atheena::coordinator::toolflow::{run_toolflow, synthetic_exit_stages, ToolflowOptions};
use atheena::dse::{
    anneal, anneal_call_count, anneal_sequential, sweep_frontier, sweep_frontier_sequential,
    AnnealConfig, ParetoConfig, Problem, ProblemKind, SweepConfig,
};
use atheena::ir::network::testnet;
use atheena::ir::Cdfg;
use atheena::resources::Board;
use atheena::runtime::DesignCache;
use atheena::sdf::HwMapping;
use atheena::sim::{
    simulate_multi, CompiledDesign, CompiledScratch, DesignTiming, SimConfig, SimScratch,
};
use atheena::trace::{NullSink, Recorder, DEFAULT_RECORDER_CAPACITY};
use atheena::util::bench::BenchLog;

const TOLERANCE: f64 = 0.25;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let save = args.iter().any(|a| a == "--save-json");
    let check = args.iter().any(|a| a == "--check");

    let net = testnet::three_exit();
    let board = Board::zc706();

    // ---- sim hot path: simulate_multi over the 3-exit pipeline ------
    let mut sim_log = BenchLog::new();
    let mut m = HwMapping::minimal(Cdfg::lower(&net, 16));
    for i in 0..m.foldings.len() {
        m.foldings[i] = m.spaces[i].max();
    }
    let timing = DesignTiming::from_ee_mapping(&m);
    let cfg = SimConfig::default();
    let batch = if quick { 1024 } else { 4096 };
    let iters = if quick { 10 } else { 30 };
    let stages = synthetic_exit_stages(&[0.4, 0.15], batch, 42);

    sim_log.bench(&format!("hotpath/simulate_multi/fresh-b{batch}"), 3, iters, || {
        simulate_multi(&timing, &cfg, &stages)
    });
    let mut scratch = SimScratch::new();
    let s = sim_log.bench(
        &format!("hotpath/simulate_multi/scratch-b{batch}"),
        3,
        iters,
        || scratch.simulate_multi(&timing, &cfg, &stages).total_cycles,
    );
    sim_log.metric(
        "hotpath/simulate_multi/samples_per_s",
        batch as f64 * s.per_second(),
        "samples/s",
    );
    // Compiled core over the identical batch (lower once, run many) —
    // the DESIGN.md §10 fast path. Target: ≥5× the interpreted scratch
    // samples/s (tracked in BENCH_sim.json `_meta`). Bit-equality with
    // the oracle is asserted before timing so a drifted kernel can
    // never post a number.
    let compiled = CompiledDesign::lower(&timing, &cfg);
    let mut cscratch = CompiledScratch::new();
    anyhow::ensure!(
        compiled.run(&mut cscratch, &stages).total_cycles
            == simulate_multi(&timing, &cfg, &stages).total_cycles,
        "compiled kernel diverged from simulate_multi on the bench batch"
    );
    let sc = sim_log.bench(
        &format!("hotpath/simulate_multi/compiled-b{batch}"),
        3,
        iters,
        || compiled.run(&mut cscratch, &stages).total_cycles,
    );
    sim_log.metric(
        "hotpath/simulate_multi/compiled_samples_per_s",
        batch as f64 * sc.per_second(),
        "samples/s",
    );
    // Tracing cost on the same schedule: the NullSink entry must track
    // the untraced scratch path (the zero-cost contract, DESIGN.md §9),
    // and the Recorder entry prices live event capture.
    let mut traced_scratch = SimScratch::new();
    sim_log.bench(
        &format!("hotpath/simulate_multi/null-sink-b{batch}"),
        3,
        iters,
        || {
            traced_scratch
                .simulate_multi_traced(&timing, &cfg, &stages, &mut NullSink)
                .total_cycles
        },
    );
    let mut recorder = Recorder::new(DEFAULT_RECORDER_CAPACITY);
    let mut rec_scratch = SimScratch::new();
    sim_log.bench(
        &format!("hotpath/simulate_multi/recorder-b{batch}"),
        3,
        iters,
        || {
            recorder.clear();
            rec_scratch
                .simulate_multi_traced(&timing, &cfg, &stages, &mut recorder)
                .total_cycles
        },
    );

    // ---- dse hot path: anneal proposals/s ---------------------------
    let mut dse_log = BenchLog::new();
    let acfg = AnnealConfig {
        iterations: if quick { 1_000 } else { 4_000 },
        restarts: 4,
        ..Default::default()
    };
    let problem = Problem::stage(0, Cdfg::lower(&net, 1), board.resources, board.clock_hz);
    let s = dse_log.bench("hotpath/anneal/parallel-restarts", 1, iters.min(10), || {
        anneal(&problem, &acfg)
    });
    let proposals = (acfg.iterations * acfg.restarts) as f64;
    dse_log.metric(
        "hotpath/anneal/proposals_per_s",
        proposals * s.per_second(),
        "proposals/s",
    );
    dse_log.bench("hotpath/anneal/sequential-restarts", 1, iters.min(10), || {
        anneal_sequential(&problem, &acfg)
    });

    // ---- incremental ladder: warm-start chaining vs cold sweep ------
    // The PR-8 headline: on the full 10-rung budget ladder the
    // warm-chained sweep must beat the cold sequential reference by ≥2×
    // wall time (target tracked in BENCH_dse.json `_meta`) while never
    // being dominated by it. The dominance gate runs before timing so a
    // degraded warm path can never post a speedup.
    let base_cdfg = Cdfg::lower_baseline(&net);
    let pcfg = ParetoConfig {
        scalings: SweepConfig::default().fractions,
        anneal: AnnealConfig {
            iterations: if quick { 600 } else { 2_000 },
            restarts: 4,
            ..Default::default()
        },
        ..Default::default()
    };
    let (_, warm_raw) = sweep_frontier(ProblemKind::Baseline, &base_cdfg, &board, &pcfg)?;
    let (_, cold_raw) =
        sweep_frontier_sequential(ProblemKind::Baseline, &base_cdfg, &board, &pcfg)?;
    for (i, (w, c)) in warm_raw.iter().zip(&cold_raw).enumerate() {
        anyhow::ensure!(
            !c.feasible || (w.feasible && w.throughput >= c.throughput * 0.95),
            "warm rung {i} dominated by cold ({} < {})",
            w.throughput,
            c.throughput
        );
    }
    let accepted: usize = warm_raw.iter().map(|r| r.accepted).sum();
    let proposed: usize = warm_raw.iter().map(|r| r.iterations_run).sum();
    dse_log.metric(
        "dse/pareto/anneal_accept_rate",
        accepted as f64 / proposed.max(1) as f64,
        "accepts/proposal",
    );
    let bench_iters = if quick { 3 } else { 5 };
    let cold_s = dse_log.bench("dse/pareto/warm_vs_cold/cold-sequential", 1, bench_iters, || {
        sweep_frontier_sequential(ProblemKind::Baseline, &base_cdfg, &board, &pcfg).unwrap()
    });
    let warm_s = dse_log.bench("dse/pareto/warm_vs_cold/warm-chained", 1, bench_iters, || {
        sweep_frontier(ProblemKind::Baseline, &base_cdfg, &board, &pcfg).unwrap()
    });
    let speedup = cold_s.mean_ns / warm_s.mean_ns.max(1.0);
    dse_log.metric("dse/pareto/warm_speedup", speedup, "x");
    println!("  -> warm-chained ladder {speedup:.2}x vs cold sweep (target >=2x)");

    // ---- e2e hot path: cold toolflow on the 3-exit testnet ----------
    let mut e2e_log = BenchLog::new();
    let opts = ToolflowOptions::quick(board.clone());
    let (_, secs) = e2e_log.once("hotpath/toolflow-cold/three_exit", || {
        run_toolflow(&net, &opts, None).unwrap()
    });
    e2e_log.metric(
        "hotpath/toolflow-cold/runs_per_s",
        1.0 / secs.max(1e-9),
        "runs/s",
    );

    // ---- warm-cache contract: zero anneal calls ---------------------
    let dir = std::env::temp_dir().join(format!("atheena-hotpath-{}", std::process::id()));
    let cache = DesignCache::open(&dir)?;
    let (_cold, was_cached) = Realized::load_or_run(&cache, &net, &opts)?;
    anyhow::ensure!(!was_cached, "hotpath cache must start cold");
    let before = anneal_call_count();
    let (warm, was_cached) = Realized::load_or_run(&cache, &net, &opts)?;
    anyhow::ensure!(was_cached, "second load_or_run must hit the cache");
    let _ = warm.measure(None)?;
    let warm_anneals = anneal_call_count() - before;
    let _ = std::fs::remove_dir_all(&dir);
    anyhow::ensure!(
        warm_anneals == 0,
        "warm-cache contract violated: {warm_anneals} anneal call(s) on a warm store"
    );
    println!("bench {:<40} ok (0 anneal calls)", "hotpath/warm-cache-contract");

    if check {
        sim_log.check_against("BENCH_sim.json", TOLERANCE)?;
        dse_log.check_against("BENCH_dse.json", TOLERANCE)?;
        e2e_log.check_against("BENCH_e2e.json", TOLERANCE)?;
    }
    if save {
        sim_log.save("BENCH_sim.json")?;
        dse_log.save("BENCH_dse.json")?;
        e2e_log.save("BENCH_e2e.json")?;
    }
    Ok(())
}
