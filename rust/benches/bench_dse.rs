//! DSE benchmarks — the Fig. 9a generator's cost (simulated-annealing
//! throughput per problem kind, full TAP-sweep wall time), the
//! resource-budget frontier sweep of `dse::pareto`, and the certified
//! optimality pass (`Realized::certify_frontier`, DESIGN.md §13).
//!
//!     cargo bench --bench bench_dse [-- --quick] [-- --save-json] [-- --check]
//!
//! `--save-json` merge-saves the recorded entries (including the
//! `dse/pareto/*` metrics) into `BENCH_dse.json` via `BenchLog`;
//! `--check` gates shared metrics against that committed baseline with
//! the standard 25% tolerance.

use atheena::coordinator::pipeline::{CertifySummary, Toolflow};
use atheena::coordinator::toolflow::ToolflowOptions;
use atheena::dse::{
    anneal, sweep_budgets, sweep_budgets_parallel, sweep_frontier, AnnealConfig,
    ExactConfig, ParetoConfig, Problem, ProblemKind, SweepConfig,
};
use atheena::ir::network::testnet;
use atheena::ir::Cdfg;
use atheena::resources::Board;
use atheena::util::bench::BenchLog;

const TOLERANCE: f64 = 0.25;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let save = args.iter().any(|a| a == "--save-json");
    let check = args.iter().any(|a| a == "--check");

    let net = testnet::blenet_like();
    let board = Board::zc706();
    let mut log = BenchLog::new();

    // Single-anneal latency per problem kind (fixed schedule).
    let iterations = if quick { 1_000 } else { 4_000 };
    let cfg = AnnealConfig {
        iterations,
        restarts: 1,
        ..Default::default()
    };
    let base_cdfg = Cdfg::lower_baseline(&net);
    let ee_cdfg = Cdfg::lower(&net, 8);
    let iters = if quick { 5 } else { 10 };

    let p = Problem::baseline(base_cdfg.clone(), board.resources, board.clock_hz);
    let s = log.bench("anneal/baseline/fixed-iters", 1, iters, || anneal(&p, &cfg));
    println!(
        "  -> {:.0} anneal-iterations/s",
        iterations as f64 * s.per_second()
    );

    let p1 = Problem::stage(0, ee_cdfg.clone(), board.resources, board.clock_hz);
    log.bench("anneal/stage1/fixed-iters", 1, iters, || anneal(&p1, &cfg));
    let p2 = Problem::stage(1, ee_cdfg.clone(), board.resources, board.clock_hz);
    log.bench("anneal/stage2/fixed-iters", 1, iters, || anneal(&p2, &cfg));

    // Resource-budget frontier sweep (dse::pareto): one anneal per
    // budget scaling on the deterministic executor, dominance filter on
    // top. The metric participates in the --check regression gate.
    let pcfg = ParetoConfig {
        scalings: if quick {
            SweepConfig::quick().fractions
        } else {
            SweepConfig::default().fractions
        },
        anneal: AnnealConfig {
            iterations,
            restarts: 2,
            ..Default::default()
        },
        ..Default::default()
    };
    let s = log.bench("dse/pareto/frontier-sweep", 1, iters.min(5), || {
        sweep_frontier(ProblemKind::Baseline, &base_cdfg, &board, &pcfg)
            .expect("frontier sweep")
    });
    log.metric(
        "dse/pareto/anneals_per_s",
        pcfg.scalings.len() as f64 * s.per_second(),
        "anneals/s",
    );

    // Certified-optimality pass (DESIGN.md §13): realize the quick
    // pipeline once under a pinned seed, then time the exact
    // branch-and-bound certification of every frontier point. The mean
    // gap is deterministic (pinned anneal seed, deterministic oracle),
    // so it participates in the --check regression gate.
    let mut topts = ToolflowOptions::quick(Board::zc706());
    topts.sweep.anneal.seed = 0xA7EE_BE9C;
    let mut realized = Toolflow::new(&net, &topts)?
        .sweep()?
        .combine()?
        .realize()?;
    let mut summary = CertifySummary::default();
    log.once("dse/exact/certify_ms", || {
        summary = realized.certify_frontier(&ExactConfig::default());
    });
    println!(
        "  -> certified {} frontier points ({} skipped), max gap {:.3}%",
        summary.certified, summary.skipped, summary.max_gap_pct
    );
    log.metric("dse/exact/mean_gap_pct", summary.mean_gap_pct, "%");

    // Full Fig. 9a-style sweeps are the expensive reference runs; skip
    // them in the CI smoke configuration.
    if !quick {
        let sweep = SweepConfig::default();
        log.once("sweep/fig9a-baseline-curve", || {
            sweep_budgets(ProblemKind::Baseline, &base_cdfg, &board, &sweep)
        });
        log.once("sweep/fig9a-stage1+stage2-curves", || {
            let a = sweep_budgets(ProblemKind::Stage(0), &ee_cdfg, &board, &sweep);
            let b = sweep_budgets(ProblemKind::Stage(1), &ee_cdfg, &board, &sweep);
            (a, b)
        });

        // Scoped-thread sweep (the pipeline's `Curves` stage): same
        // curves, one anneal task per budget fraction drained by a
        // worker pool.
        log.once("sweep/fig9a-baseline-curve/parallel", || {
            sweep_budgets_parallel(ProblemKind::Baseline, &base_cdfg, &board, &sweep)
        });
        log.once("sweep/fig9a-stage1+stage2-curves/parallel", || {
            let a = sweep_budgets_parallel(ProblemKind::Stage(0), &ee_cdfg, &board, &sweep);
            let b = sweep_budgets_parallel(ProblemKind::Stage(1), &ee_cdfg, &board, &sweep);
            (a, b)
        });
    }

    if check {
        log.check_against("BENCH_dse.json", TOLERANCE)?;
    }
    if save {
        log.save("BENCH_dse.json")?;
    }
    Ok(())
}
