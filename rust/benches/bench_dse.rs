//! DSE benchmarks — the Fig. 9a generator's cost: simulated-annealing
//! throughput per problem kind and full TAP-sweep wall time.
//!
//!     cargo bench --bench bench_dse

use atheena::dse::{
    anneal, sweep_budgets, sweep_budgets_parallel, AnnealConfig, Problem, ProblemKind,
    SweepConfig,
};
use atheena::ir::network::testnet;
use atheena::ir::Cdfg;
use atheena::resources::Board;
use atheena::util::bench::{bench, once};

fn main() {
    let net = testnet::blenet_like();
    let board = Board::zc706();

    // Single-anneal latency per problem kind (fixed schedule).
    let cfg = AnnealConfig {
        iterations: 4_000,
        restarts: 1,
        ..Default::default()
    };
    let base_cdfg = Cdfg::lower_baseline(&net);
    let ee_cdfg = Cdfg::lower(&net, 8);

    let p = Problem::baseline(base_cdfg.clone(), board.resources, board.clock_hz);
    let s = bench("anneal/baseline/4k-iters", 1, 10, || anneal(&p, &cfg));
    println!(
        "  -> {:.0} anneal-iterations/s",
        4_000.0 * s.per_second()
    );

    let p1 = Problem::stage(0, ee_cdfg.clone(), board.resources, board.clock_hz);
    bench("anneal/stage1/4k-iters", 1, 10, || anneal(&p1, &cfg));
    let p2 = Problem::stage(1, ee_cdfg.clone(), board.resources, board.clock_hz);
    bench("anneal/stage2/4k-iters", 1, 10, || anneal(&p2, &cfg));

    // Full Fig. 9a-style sweep (default fractions ladder).
    let sweep = SweepConfig::default();
    once("sweep/fig9a-baseline-curve", || {
        sweep_budgets(ProblemKind::Baseline, &base_cdfg, &board, &sweep)
    });
    once("sweep/fig9a-stage1+stage2-curves", || {
        let a = sweep_budgets(ProblemKind::Stage(0), &ee_cdfg, &board, &sweep);
        let b = sweep_budgets(ProblemKind::Stage(1), &ee_cdfg, &board, &sweep);
        (a, b)
    });

    // Scoped-thread sweep (the pipeline's `Curves` stage): same curves,
    // one anneal task per budget fraction drained by a worker pool.
    once("sweep/fig9a-baseline-curve/parallel", || {
        sweep_budgets_parallel(ProblemKind::Baseline, &base_cdfg, &board, &sweep)
    });
    once("sweep/fig9a-stage1+stage2-curves/parallel", || {
        let a = sweep_budgets_parallel(ProblemKind::Stage(0), &ee_cdfg, &board, &sweep);
        let b = sweep_budgets_parallel(ProblemKind::Stage(1), &ee_cdfg, &board, &sweep);
        (a, b)
    });
}
