//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. optimizer choice — simulated annealing (fpgaConvNet's) vs greedy
//!    hill-climb vs random search at equal evaluation budgets,
//! 2. allocation policy — Eq. 1 probability-aware combination vs the §III
//!    naive "all stages at highest throughput" strawman,
//! 3. buffer-margin policy — throughput robustness vs BRAM cost.
//!
//!     cargo bench --bench bench_ablation

use atheena::coordinator::toolflow::synthetic_hard_flags;
use atheena::dse::{
    anneal, greedy, naive_combine, random_search, sweep_budgets, AnnealConfig,
    Problem, ProblemKind, SweepConfig,
};
use atheena::ir::network::testnet;
use atheena::ir::Cdfg;
use atheena::resources::Board;
use atheena::sdf::buffering;
use atheena::sim::{simulate_ee, DesignTiming, SimConfig, SimMetrics};
use atheena::tap::combine;
use atheena::util::bench::once;

fn main() {
    let net = testnet::blenet_like();
    let board = Board::zc706();

    // ---- 1. optimizer ablation ----
    println!("== optimizer ablation (baseline problem, budget ladder) ==");
    println!(
        "{:>8} {:>14} {:>14} {:>14}",
        "budget%", "SA(thr)", "greedy(thr)", "random(thr)"
    );
    for frac in [0.2, 0.4, 0.6, 0.85] {
        let p = Problem::baseline(
            Cdfg::lower_baseline(&net),
            board.budget(frac),
            board.clock_hz,
        );
        let sa = anneal(&p, &AnnealConfig::default());
        let gr = greedy(&p);
        let rs = random_search(&p, &AnnealConfig::default());
        println!(
            "{:>8.0} {:>14.0} {:>14.0} {:>14.0}",
            frac * 100.0,
            sa.throughput,
            gr.throughput,
            rs.throughput
        );
    }
    let p = Problem::baseline(
        Cdfg::lower_baseline(&net),
        board.budget(0.5),
        board.clock_hz,
    );
    once("ablate/sa-default-schedule", || {
        anneal(&p, &AnnealConfig::default())
    });
    once("ablate/greedy", || greedy(&p));
    once("ablate/random-equal-evals", || {
        random_search(&p, &AnnealConfig::default())
    });

    // ---- 2. allocation-policy ablation ----
    println!("\n== allocation ablation: Eq.1 vs naive (p = 0.25) ==");
    let ee_cdfg = Cdfg::lower(&net, 1);
    let sweep = SweepConfig::default();
    let (f, s1_results) = sweep_budgets(ProblemKind::Stage(0), &ee_cdfg, &board, &sweep);
    let (g, _) = sweep_budgets(ProblemKind::Stage(1), &ee_cdfg, &board, &sweep);
    let _ = &s1_results;
    println!(
        "{:>8} {:>16} {:>16} {:>8}",
        "budget%", "eq1 thr@q=p", "naive thr@q=p", "gain"
    );
    for frac in [0.3, 0.5, 0.7, 1.0] {
        let budget = board.budget(frac);
        let eq1 = combine(&f, &g, 0.25, &budget).map(|d| d.throughput_at(0.25));
        let naive = naive_combine(&f, &g, &budget).map(|d| d.throughput_at(0.25));
        match (eq1, naive) {
            (Some(a), Some(b)) => println!(
                "{:>8.0} {:>16.0} {:>16.0} {:>7.2}x",
                frac * 100.0,
                a,
                b,
                a / b
            ),
            _ => println!("{:>8.0} (infeasible)", frac * 100.0),
        }
    }

    // ---- 3. buffer-margin ablation ----
    println!("\n== buffer-margin ablation (simulated, q = p + 10%) ==");
    let p1 = Problem::stage(0, ee_cdfg.clone(), board.budget(0.85), board.clock_hz);
    let s1 = anneal(&p1, &AnnealConfig::default());
    let p2 = Problem::stage(1, ee_cdfg.clone(), board.budget(0.3), board.clock_hz);
    let s2 = anneal(&p2, &AnnealConfig::default());
    let mut mapping = s1.mapping.clone();
    for n in &mapping.cdfg.nodes.clone() {
        if n.stage == atheena::ir::StageId::Backbone(1) {
            mapping.foldings[n.id] = s2.mapping.foldings[n.id];
        }
    }
    let min_depth = buffering::min_depth_samples(&mapping, 0);
    println!(
        "{:>8} {:>7} {:>7} {:>16} {:>10}",
        "margin", "depth", "BRAM", "thr(samples/s)", "stalls"
    );
    for margin in [0usize, 4, 16, 48, 128] {
        mapping.set_cond_buffer_depth(0, min_depth + margin);
        let timing = DesignTiming::from_ee_mapping(&mapping);
        let flags = synthetic_hard_flags(0.35, 1024, 0xAB1A);
        let m = SimMetrics::from_result(
            &simulate_ee(&timing, &SimConfig::default(), &flags),
            board.clock_hz,
        );
        println!(
            "{:>8} {:>7} {:>7} {:>16.0} {:>10}",
            margin,
            min_depth + margin,
            mapping.total_resources().bram,
            m.throughput_sps,
            m.stall_cycles
        );
    }
}
