//! TAP machinery benchmarks — Eq. 1 combination cost vs curve size, and
//! Pareto filtering (the optimizer-side cost of the ATHEENA extension).
//!
//!     cargo bench --bench bench_tap

use atheena::resources::ResourceVec;
use atheena::tap::{combine, combine_multi, TapCurve, TapPoint};
use atheena::util::bench::bench;
use atheena::util::Rng;

fn random_curve(n: usize, seed: u64) -> TapCurve {
    let mut rng = Rng::new(seed);
    let pts = (0..n)
        .map(|i| {
            let dsp = 50 + rng.below(800) as u64;
            TapPoint {
                resources: ResourceVec::new(dsp * 90, dsp * 140, dsp, 20 + dsp / 4),
                throughput: dsp as f64 * (40.0 + 20.0 * rng.f64()),
                ii: 1 + rng.below(10_000) as u64,
                budget_fraction: 0.0,
                source: i,
            }
        })
        .collect();
    TapCurve::from_points(pts)
}

fn main() {
    for n in [10usize, 50, 200, 1000] {
        let raw: Vec<TapPoint> = {
            let c = random_curve(n, 1);
            c.points
        };
        bench(&format!("tap/pareto-filter/{n}-points"), 5, 50, || {
            TapCurve::from_points(raw.clone())
        });
    }

    let budget = ResourceVec::new(218_600, 437_200, 900, 1_090);
    for n in [10usize, 50, 200] {
        let f = random_curve(n, 2);
        let g = random_curve(n, 3);
        let s = bench(&format!("tap/combine-eq1/{n}x{n}-pairs"), 5, 100, || {
            combine(&f, &g, 0.25, &budget)
        });
        println!(
            "  -> {:.2} M pair-evaluations/s",
            (f.points.len() * g.points.len()) as f64 * s.per_second() / 1e6
        );
    }

    // Multi-stage Eq. 1 (the N-exit generalization): branch-and-bound
    // over N Pareto sets. Curve sizes match a default sweep ladder.
    for n_stages in [3usize, 4] {
        let curves: Vec<TapCurve> = (0..n_stages)
            .map(|i| random_curve(30, 10 + i as u64))
            .collect();
        // Non-increasing reach probabilities: 1, 0.3, 0.12, 0.05…
        let reach: Vec<f64> = (0..n_stages)
            .map(|i| match i {
                0 => 1.0,
                1 => 0.3,
                2 => 0.12,
                _ => 0.05,
            })
            .collect();
        let s = bench(
            &format!("tap/combine-multi/{n_stages}-stages-30pts"),
            5,
            50,
            || combine_multi(&curves, &reach, &budget),
        );
        println!(
            "  -> {:.1} k combinations/s upper bound space {}",
            s.per_second() / 1e3,
            30usize.pow(n_stages as u32)
        );
    }

    // Eq. 1 across a budget ladder (the combined-curve trace of Fig. 9a).
    let f = random_curve(60, 4);
    let g = random_curve(60, 5);
    let ladder: Vec<ResourceVec> = (1..=10)
        .map(|i| budget.scaled(i as f64 / 10.0))
        .collect();
    bench("tap/combined-curve/10-budgets", 5, 50, || {
        ladder
            .iter()
            .map(|b| combine(&f, &g, 0.25, b))
            .collect::<Vec<_>>()
    });
}
