//! Simulator benchmarks — the Fig. 9b / Table I measurement engine:
//! batch-1024 simulation latency across q values, batch-size scaling,
//! and the closed-loop drift path.
//!
//!     cargo bench --bench bench_sim [-- --quick] [-- --save-json]
//!
//! `--quick` trims iterations/batches for CI smoke runs; `--save-json`
//! writes the results to `BENCH_sim.json` so the perf trajectory is
//! tracked run over run.

use atheena::coordinator::toolflow::synthetic_hard_flags;
use atheena::ee::decision::{Controller, Fixed};
use atheena::ir::network::testnet;
use atheena::ir::Cdfg;
use atheena::sdf::HwMapping;
use atheena::sim::{
    design_operating_point, simulate_baseline, simulate_closed_loop, simulate_ee,
    ClosedLoopConfig, DesignTiming, DriftScenario, SimConfig,
};
use atheena::util::bench::BenchLog;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let save = args.iter().any(|a| a == "--save-json");
    let mut log = BenchLog::new();

    let net = testnet::blenet_like();
    let mut m = HwMapping::minimal(Cdfg::lower(&net, 16));
    // Unroll to a realistic operating point.
    for i in 0..m.foldings.len() {
        m.foldings[i] = m.spaces[i].max();
    }
    let timing = DesignTiming::from_ee_mapping(&m);
    let cfg = SimConfig::default();
    let iters = if quick { 5 } else { 30 };

    // Fig. 9b inner loop: one simulated board measurement per (design, q).
    for q in [0.20, 0.25, 0.30] {
        let flags = synthetic_hard_flags(q, 1024, 42);
        let s = log.bench(&format!("sim/ee-batch1024/q={q:.2}"), 3, iters, || {
            simulate_ee(&timing, &cfg, &flags)
        });
        println!(
            "  -> {:.1} M simulated-samples/s",
            1024.0 * s.per_second() / 1e6
        );
    }

    // Baseline measurement (Table I's B rows).
    log.bench("sim/baseline-batch1024", 3, iters, || {
        simulate_baseline(&timing, &cfg, 1024)
    });

    // Batch scaling (the DMA-to-idle measurement window).
    let batches: &[usize] = if quick {
        &[256, 1024]
    } else {
        &[256, 1024, 4096, 16384]
    };
    for &n in batches {
        let flags = synthetic_hard_flags(0.25, n, 7);
        log.bench(&format!("sim/ee-batch{n}"), 2, iters.min(15), || {
            simulate_ee(&timing, &cfg, &flags)
        });
    }

    // Stall-heavy regime (undersized buffer) — worst-case engine load.
    let mut tight = timing.clone();
    tight.set_cond_buffer_depth(0, 1)?;
    let flags = synthetic_hard_flags(0.5, 1024, 9);
    log.bench("sim/ee-batch1024/depth1-stalls", 3, iters, || {
        simulate_ee(&tight, &cfg, &flags)
    });

    // Closed-loop drift path: fixed vs controller over a step shift —
    // the operating-point control loop's per-sample overhead.
    let op = design_operating_point(&[0.25]);
    let run = ClosedLoopConfig {
        samples: if quick { 4096 } else { 16384 },
        window: 1024,
        seed: 0xBE7C,
    };
    let drift = DriftScenario::Step { at: 0.5, to: 2.0 };
    log.bench("sim/closed-loop/fixed", 2, iters.min(15), || {
        let mut policy = Fixed::new(op.clone());
        simulate_closed_loop(&timing, &cfg, &mut policy, &drift, &run)
    });
    log.bench("sim/closed-loop/controller", 2, iters.min(15), || {
        let mut policy = Controller::new(op.clone(), 1024);
        simulate_closed_loop(&timing, &cfg, &mut policy, &drift, &run)
    });

    if save {
        log.save("BENCH_sim.json")?;
    }
    Ok(())
}
