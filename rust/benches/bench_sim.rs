//! Simulator benchmarks — the Fig. 9b / Table I measurement engine:
//! batch-1024 simulation latency across q values and batch-size scaling.
//!
//!     cargo bench --bench bench_sim

use atheena::coordinator::toolflow::synthetic_hard_flags;
use atheena::ir::network::testnet;
use atheena::ir::Cdfg;
use atheena::sdf::HwMapping;
use atheena::sim::{simulate_baseline, simulate_ee, DesignTiming, SimConfig};
use atheena::util::bench::bench;

fn main() {
    let net = testnet::blenet_like();
    let mut m = HwMapping::minimal(Cdfg::lower(&net, 16));
    // Unroll to a realistic operating point.
    for i in 0..m.foldings.len() {
        m.foldings[i] = m.spaces[i].max();
    }
    let timing = DesignTiming::from_ee_mapping(&m);
    let cfg = SimConfig::default();

    // Fig. 9b inner loop: one simulated board measurement per (design, q).
    for q in [0.20, 0.25, 0.30] {
        let flags = synthetic_hard_flags(q, 1024, 42);
        let s = bench(
            &format!("sim/ee-batch1024/q={q:.2}"),
            3,
            30,
            || simulate_ee(&timing, &cfg, &flags),
        );
        println!(
            "  -> {:.1} M simulated-samples/s",
            1024.0 * s.per_second() / 1e6
        );
    }

    // Baseline measurement (Table I's B rows).
    bench("sim/baseline-batch1024", 3, 30, || {
        simulate_baseline(&timing, &cfg, 1024)
    });

    // Batch scaling (the DMA-to-idle measurement window).
    for n in [256usize, 1024, 4096, 16384] {
        let flags = synthetic_hard_flags(0.25, n, 7);
        bench(&format!("sim/ee-batch{n}"), 2, 15, || {
            simulate_ee(&timing, &cfg, &flags)
        });
    }

    // Stall-heavy regime (undersized buffer) — worst-case engine load.
    let mut tight = timing.clone();
    tight.set_cond_buffer_depth(0, 1);
    let flags = synthetic_hard_flags(0.5, 1024, 9);
    bench("sim/ee-batch1024/depth1-stalls", 3, 30, || {
        simulate_ee(&tight, &cfg, &flags)
    });
}
